//! **BoundPipeline** — a compiled pipeline bound to a prepared graph: the
//! cheap per-query layer of the lifecycle. Everything one-time (translate,
//! synthesis, flash, Reorder/Partition/Layout, graph transport, artifact
//! lookup, **scheduler admission**) already happened; [`BoundPipeline::query`]
//! only pays the superstep loop — the paper's "tens of seconds to
//! generate, then many fast traversals" economics as an API shape.
//!
//! The binding itself is **immutable during queries**: all mutable
//! per-query state (scheduler progress, simulator cycles, the trace log,
//! DMA records) lives in a per-query [`QueryContext`], so [`BoundPipeline::query`]
//! takes `&self` and any number of queries can run concurrently over the
//! shared design + graph — see [`BoundPipeline::run_batch_parallel`].
//! [`BoundPipeline::run`]/[`BoundPipeline::run_batch`] remain as thin
//! `&mut self` compatibility wrappers producing identical reports.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::accel::multipe::{InterconnectModel, MultiPeSimulator};
use crate::accel::simulator::{AccelSimulator, EdgeBatch, LAUNCH_SECONDS};
use crate::accel::stats::{CycleBreakdown, SimStats, SuperstepSim};
use crate::comm::{CommManager, TransferRecord};
use crate::prep::prepared::PreparedGraph;
use crate::sched::faults::{self, Seam};
use crate::sched::{
    available_workers, AdmittedPlan, Deadline, DeadlineExceeded, FaultPlan, InjectedFault,
    ParallelismPlan, RuntimeScheduler, WorkerBudget, WorkerPanic,
};

use crate::dsl::program::{Direction, GasProgram};

use super::compiled::{CompiledPipeline, RunOptions};
use super::executor::ORACLE_TOLERANCE;
use super::gas::{self, SuperstepTrace};
use super::metrics::{FunctionalPath, RunReport};
use super::sharded::{run_sharded_with_faults, ShardedSuperstepTrace};
use super::trace::Trace;
use super::xla_engine;

/// All mutable state of **one** query in flight: its scheduler (superstep
/// progress against the iteration cap), its cycle simulator, its trace
/// log, and the DMA records it produced. Self-contained by construction —
/// two contexts never share a cache line of mutable state — which is what
/// lets many queries run concurrently over one immutable
/// [`BoundPipeline`].
#[derive(Debug)]
pub struct QueryContext {
    scheduler: RuntimeScheduler,
    sim: AccelSimulator,
    /// Multi-PE simulator + the binding's shard→PE placement, present
    /// only on sharded queries (partitioned bindings). When set, the
    /// query's simulated workload comes from the multi-PE critical path
    /// driven by real per-shard traces, not from `sim`.
    multipe: Option<(MultiPeSimulator, Vec<u32>)>,
    /// Edges traversed across all sharded supersteps (feeds the
    /// synthesized [`SimStats`]).
    mp_edges: u64,
    /// Sharded supersteps where at least one shard pulled.
    mp_pull: u32,
    /// Pipeline fill/drain depth (cycles) for sharded trace rows.
    pipeline_depth: u64,
    /// Reused merge buffer for auto-sharded supersteps: the per-shard
    /// destination streams concatenated in shard order, fed to the
    /// single-PE simulator as one monolithic-style batch.
    merged: Vec<u32>,
    trace: Trace,
    /// DMA records modeled (not yet committed) by this query; the engine
    /// folds them into the shared [`CommManager`] ledger in query order.
    transfers: Vec<TransferRecord>,
    bytes_per_edge: u64,
    avg_edge_gap: f64,
    want_trace: bool,
    /// This query's wall-clock budget, checked at every superstep
    /// boundary (all three engines route through one of the observer
    /// methods below).
    deadline: Option<Deadline>,
    /// This query's fault-injection schedule (superstep seam).
    faults: Option<Arc<FaultPlan>>,
}

impl QueryContext {
    fn new(bound: &BoundPipeline<'_>, cap: u32, opts: &RunOptions) -> Self {
        let want_trace = opts.trace_path.is_some();
        let pipeline = bound.pipeline;
        // Sharded queries route every shard's destination stream into its
        // own PE's reduce banks; boundary messages serialize on the
        // interconnect. Placement was fixed at bind time.
        let multipe = bound.graph.sharded().map(|sg| {
            let sim = MultiPeSimulator::new(
                pipeline.device.clone(),
                pipeline.design.pipeline,
                InterconnectModel::default(),
            );
            (sim, bound.admitted.place_shards(sg.num_shards))
        });
        Self {
            // Reuse the plan granted at bind time: no per-query resource
            // re-validation.
            scheduler: bound.admitted.scheduler(cap),
            sim: AccelSimulator::new(pipeline.device.clone(), pipeline.design.pipeline),
            multipe,
            mp_edges: 0,
            mp_pull: 0,
            pipeline_depth: pipeline.design.pipeline.depth as u64,
            merged: Vec::new(),
            trace: Trace::default(),
            transfers: Vec::with_capacity(1),
            bytes_per_edge: if pipeline.program.uses_weights { 12 } else { 8 },
            avg_edge_gap: bound.graph.avg_edge_gap,
            want_trace,
            deadline: opts.deadline,
            faults: opts.faults.clone(),
        }
    }

    /// The cooperative cancellation point every engine shares: deadline
    /// check (typed [`DeadlineExceeded`] with supersteps-completed
    /// accounting) plus the superstep fault seam. Runs right after
    /// scheduler admission of superstep `index`, on all three engine
    /// paths (monolithic, sharded, auto-sharded).
    fn guard_superstep(&self, index: u32) -> Result<()> {
        if let Some(deadline) = &self.deadline {
            deadline.check(self.scheduler.supersteps())?;
        }
        if let Some(plan) = &self.faults {
            plan.trip(Seam::Superstep, index as u64)?;
        }
        Ok(())
    }

    /// Lockstep observer body: account one superstep in the scheduler and
    /// the cycle simulator. Errors (the iteration cap, an expired
    /// deadline) abort the run.
    fn superstep(&mut self, trace: &SuperstepTrace<'_>) -> Result<()> {
        self.scheduler.begin_superstep(trace.active_rows as usize)?;
        self.guard_superstep(trace.index)?;
        let step = self.sim.superstep(&EdgeBatch {
            dsts: trace.dsts,
            active_rows: trace.active_rows,
            bytes_per_edge: self.bytes_per_edge,
            avg_edge_gap: self.avg_edge_gap,
            direction: trace.direction,
        });
        if self.want_trace {
            self.trace.record(step);
        }
        self.scheduler.end_superstep(trace.dsts.len());
        Ok(())
    }

    /// Sharded lockstep observer body: account one superstep in the
    /// scheduler and drive the multi-PE simulator with the engine's real
    /// per-shard destination streams and boundary-message counts.
    fn sharded_superstep(&mut self, trace: &ShardedSuperstepTrace<'_>) -> Result<()> {
        self.scheduler.begin_superstep(trace.active_rows as usize)?;
        self.guard_superstep(trace.index)?;
        let (mp, pe_of_shard) =
            self.multipe.as_mut().expect("sharded superstep requires a partitioned binding");
        let step = mp.superstep_shards(trace.shard_dsts, trace.shard_crossing, pe_of_shard);
        let edges: u64 = trace.shard_dsts.iter().map(|d| d.len() as u64).sum();
        self.mp_edges += edges;
        let pulled = trace.directions.contains(&Direction::Pull);
        if pulled {
            self.mp_pull += 1;
        }
        if self.want_trace {
            self.trace.record(SuperstepSim {
                index: trace.index,
                edges,
                active_vertices: trace.active_rows,
                direction: if pulled { Direction::Pull } else { Direction::Push },
                shards: trace.shard_dsts.len() as u32,
                cycles: CycleBreakdown {
                    compute: step.critical_cycles,
                    fill_drain: self.pipeline_depth,
                    ..Default::default()
                },
                launch_seconds: LAUNCH_SECONDS,
            });
        }
        self.scheduler.end_superstep(edges as usize);
        Ok(())
    }

    /// Auto-sharded lockstep observer body: the engine fanned the
    /// superstep across worker threads, but the *binding* is
    /// un-partitioned — one simulated accelerator — so the per-shard
    /// destination streams fold back into a single monolithic-style
    /// [`EdgeBatch`] for the single-PE simulator. Shards are concatenated
    /// in shard order (destination ownership makes that the monolithic
    /// stream re-ordered by owner range); the batch direction is `Pull`
    /// iff any shard pulled, matching the engine's `pull_supersteps`
    /// accounting.
    fn auto_sharded_superstep(&mut self, trace: &ShardedSuperstepTrace<'_>) -> Result<()> {
        self.scheduler.begin_superstep(trace.active_rows as usize)?;
        self.guard_superstep(trace.index)?;
        self.merged.clear();
        for dsts in trace.shard_dsts {
            self.merged.extend_from_slice(dsts);
        }
        let direction = if trace.directions.contains(&Direction::Pull) {
            Direction::Pull
        } else {
            Direction::Push
        };
        let step = self.sim.superstep(&EdgeBatch {
            dsts: &self.merged,
            active_rows: trace.active_rows,
            bytes_per_edge: self.bytes_per_edge,
            avg_edge_gap: self.avg_edge_gap,
            direction,
        });
        if self.want_trace {
            self.trace.record(step);
        }
        self.scheduler.end_superstep(self.merged.len());
        Ok(())
    }
}

/// A compiled pipeline bound to one prepared graph, ready for repeated
/// queries. Borrowing the [`CompiledPipeline`] keeps the design shared:
/// many bound graphs can coexist on one compile.
pub struct BoundPipeline<'p> {
    pipeline: &'p CompiledPipeline,
    graph: Arc<PreparedGraph>,
    comm: CommManager,
    /// Plan granted by scheduler admission — decided once at bind time and
    /// reused by every query.
    admitted: AdmittedPlan,
    /// Modeled deployment seconds (flash + graph transport), paid at bind
    /// time and reported — not re-paid — by every query.
    deploy_seconds: f64,
    queries_run: AtomicU64,
}

impl<'p> BoundPipeline<'p> {
    pub(crate) fn new(
        pipeline: &'p CompiledPipeline,
        graph: Arc<PreparedGraph>,
        comm: CommManager,
        admitted: AdmittedPlan,
        deploy_seconds: f64,
    ) -> Self {
        Self { pipeline, graph, comm, admitted, deploy_seconds, queries_run: AtomicU64::new(0) }
    }

    pub fn pipeline(&self) -> &CompiledPipeline {
        self.pipeline
    }

    pub fn graph(&self) -> &PreparedGraph {
        &self.graph
    }

    /// The parallelism plan the scheduler granted at bind time.
    pub fn granted_plan(&self) -> ParallelismPlan {
        self.admitted.granted
    }

    /// Shared transfer accounting (graph transport + committed query
    /// read-backs).
    pub fn comm(&self) -> &CommManager {
        &self.comm
    }

    /// Modeled deployment seconds paid when this binding was created.
    pub fn deploy_seconds(&self) -> f64 {
        self.deploy_seconds
    }

    /// Modeled one-time seconds amortized across queries on this binding
    /// (preparation + compilation + deployment — the Fig. 5 periods).
    pub fn setup_seconds(&self) -> f64 {
        self.graph.prep_seconds + self.pipeline.compile_seconds() + self.deploy_seconds
    }

    /// Queries served by this binding so far.
    pub fn queries_run(&self) -> u64 {
        self.queries_run.load(Ordering::Relaxed)
    }

    /// The iteration cap for one query: the program's own superstep bound
    /// (floored at [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`] so short programs
    /// still have headroom before the safety net trips), optionally
    /// **tightened** by the per-query override. The interpreter never runs
    /// past the program bound, so an override above it is clamped rather
    /// than silently ignored.
    ///
    /// [`DELTA_CONVERGENCE_SUPERSTEP_BOUND`]: crate::dsl::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND
    fn cap_for(&self, opts: &RunOptions) -> u32 {
        let n = self.graph.csr.num_vertices();
        let bound = self
            .pipeline
            .program
            .max_supersteps(n)
            .max(crate::dsl::program::DELTA_CONVERGENCE_SUPERSTEP_BOUND);
        opts.max_supersteps.map_or(bound, |cap| cap.min(bound))
    }

    /// The per-query core: runs one query against `&self`, returning the
    /// report plus the query's uncommitted DMA records. Callers decide
    /// when to fold the records into the shared ledger — immediately
    /// ([`Self::query`]) or after a parallel join in query order
    /// ([`Self::run_batch_parallel`]) so totals are bit-identical to the
    /// sequential path.
    fn run_query(&self, opts: &RunOptions) -> Result<(RunReport, Vec<TransferRecord>)> {
        let pipeline = self.pipeline;
        let design = &pipeline.design;
        let csr = &self.graph.csr;

        // --- fault-tolerance preamble: an already-expired deadline (e.g.
        //     deadline_us=0, or a long queue wait) aborts before any work,
        //     and the exec fault seam fires here. The exec token folds in
        //     the attempt number, so `#root` rules hit the first attempt
        //     only and a retry runs clean.
        if let Some(deadline) = &opts.deadline {
            deadline.check(0)?;
        }
        if let Some(plan) = &opts.faults {
            plan.trip(Seam::Exec, faults::exec_token(opts.root, opts.attempt))?;
        }

        // --- bind runtime parameters: resolve the query's ParamSet
        //     against the declared signature and specialize the program.
        //     This is the *only* per-value work — the compiled design,
        //     binding, and admission are shared across all values.
        let resolved = pipeline
            .program
            .resolve_params(&opts.params)
            .map_err(|e| anyhow::Error::msg(format!("query parameters: {e}")))?;
        let instantiated: GasProgram;
        let program: &GasProgram = if pipeline.program.has_runtime_params() {
            instantiated = pipeline
                .program
                .instantiate_resolved(&resolved)
                .map_err(|e| anyhow::Error::msg(format!("query parameters: {e}")))?;
            &instantiated
        } else {
            &pipeline.program
        };

        // --- functional run (software oracle) in lockstep with the cycle
        //     simulator; the scheduler's iteration cap aborts the loop.
        //     Direction-optimized: pull supersteps execute over the CSC
        //     (plus out-degrees and the pull trace stream) lazily built
        //     once per prepared graph and shared by every query on this
        //     binding, with per-superstep choices flowing into the
        //     simulator through the trace. Push-only-pinned queries never
        //     touch (or build) those caches.
        let cap = self.cap_for(opts);
        let mut ctx = QueryContext::new(self, cap, opts);
        // Partitioned bindings execute the sharded engine: one shard per
        // part, per-shard push/pull decisions, threaded shard workers —
        // bit-identical values to the monolithic interpreter (the
        // destination-ownership invariant; property-tested).
        let sharded = self.graph.sharded();
        let num_shards = sharded.map_or(0, |sg| sg.num_shards);
        // Un-partitioned bindings auto-shard for intra-superstep thread
        // parallelism (degree-balanced destination ranges; see
        // `PreparedGraph::auto_sharded`). The decision is static per
        // binding — it never consults the momentary budget — so every
        // query takes the same execution path and sequential vs
        // batch-parallel reports stay bit-identical.
        let auto = if sharded.is_some() {
            None
        } else {
            self.graph.auto_sharded_for(opts.direction == gas::DirectionPolicy::PushOnly)
        };
        let auto_shards = auto.map_or(0, |sg| sg.num_shards as u32);
        let view = if sharded.is_some() || auto.is_some() {
            // shards carry their own CSR/CSC slices; the monolithic view
            // only supplies init sizing and PageRank out-degrees
            self.graph.engine_view()
        } else if opts.direction == gas::DirectionPolicy::PushOnly {
            gas::EngineGraph::push_only(csr)
        } else if program.is_damped_pagerank() {
            // full-sweep pull runs stream the same O(E) trace every
            // superstep — hand them the per-graph cache
            self.graph.engine_view().with_pull_stream(self.graph.pull_stream())
        } else {
            self.graph.engine_view()
        };
        let mut crossing_msgs = 0u64;
        let oracle = match (sharded, auto) {
            (Some(sg), _) => {
                // Worker pool: the requested (or default one-per-shard,
                // capped at the machine) size, leased from the global
                // budget so batch × shard nesting divides the cores
                // instead of multiplying. Results are identical at every
                // granted size.
                let want = opts
                    .shard_workers
                    .unwrap_or_else(|| sg.num_shards.min(available_workers()))
                    .max(1);
                let lease = WorkerBudget::global().lease(want);
                let run = run_sharded_with_faults(
                    program,
                    &view,
                    sg,
                    opts.root,
                    opts.direction,
                    lease.workers(),
                    opts.faults.as_deref(),
                    |t| ctx.sharded_superstep(t),
                )?;
                crossing_msgs = run.crossing_msgs;
                run.result
            }
            (None, Some(sg)) => {
                // Auto-sharded: threads are an execution detail of the
                // monolithic sweep, not a deployment shape — the report
                // keeps monolithic accounting (`shards` 0, no exchange
                // billing; the host never pays boundary DMA for shards
                // that share one memory).
                let want = opts
                    .shard_workers
                    .unwrap_or_else(available_workers)
                    .clamp(1, sg.num_shards);
                let lease = WorkerBudget::global().lease(want);
                let run = run_sharded_with_faults(
                    program,
                    &view,
                    sg,
                    opts.root,
                    opts.direction,
                    lease.workers(),
                    opts.faults.as_deref(),
                    |t| ctx.auto_sharded_superstep(t),
                )?;
                run.result
            }
            (None, None) => {
                gas::run_with_policy(program, &view, opts.root, opts.direction, |trace| {
                    ctx.superstep(trace)
                })?
            }
        };
        // The interpreter self-limits at the program's own superstep bound;
        // exhausting that bound without meeting the convergence condition
        // is the same failure the scheduler cap guards against, so it must
        // abort the query, not return truncated values.
        if !oracle.converged {
            anyhow::bail!(
                "iteration cap hit: {:?} did not converge within {} supersteps",
                program.name,
                oracle.supersteps
            );
        }
        ctx.scheduler.converged();

        // --- AOT/XLA path for canonical programs (registry resolved at
        //     compile time; absent registry = software fallback)
        let mut functional_path = FunctionalPath::Software;
        let mut functional_exec_seconds = 0.0;
        let mut oracle_deviation = None;
        let mut edges_traversed = oracle.edges_traversed;
        let mut supersteps = oracle.supersteps;
        // The XLA path reads its scalars from the query context too: the
        // bound tolerance drives the kernel's convergence check. The AOT
        // PR kernel bakes damping at 0.85 (python/compile/kernels), so a
        // query bound to any other damping takes the software oracle —
        // correct answers always win over the fast path.
        let tolerance = match &program.convergence {
            crate::dsl::program::Convergence::DeltaBelow(t) => {
                t.as_lit().unwrap_or(opts.tolerance)
            }
            _ => opts.tolerance,
        };
        let damping_ok = match &program.writeback {
            crate::dsl::program::Writeback::DampedSum(d) => {
                d.as_lit().is_some_and(|v| (v - xla_engine::XLA_PR_DAMPING).abs() < 1e-12)
            }
            _ => true,
        };
        // ... and the AOT kernels traverse unbounded: a finite bound depth
        // horizon must stay on the software oracle too.
        let depth_ok = program
            .depth_limit
            .as_ref()
            .and_then(|s| s.as_lit())
            .is_none_or(f64::is_infinite);
        let xla_compatible = damping_ok && depth_ok;
        if opts.use_xla && xla_compatible {
            if let (Some(kind), Some(registry)) = (program.kind, pipeline.registry.as_ref()) {
                let xla = xla_engine::run(registry, kind, csr, opts.root, tolerance)?;
                functional_path = FunctionalPath::Xla;
                functional_exec_seconds = xla.exec_seconds;
                edges_traversed = xla.edges_traversed.max(edges_traversed);
                supersteps = xla.supersteps;
                if opts.verify {
                    let dev = xla_engine::max_deviation(&xla.values, &oracle.values);
                    if dev > ORACLE_TOLERANCE {
                        anyhow::bail!(
                            "XLA functional result deviates from the software \
                             oracle by {dev:.3e} (> {ORACLE_TOLERANCE:.0e})"
                        );
                    }
                    oracle_deviation = Some(dev);
                }
            }
        }

        // results DMA back (vertex values): modeled here, committed to the
        // shared ledger by the caller
        let QueryContext { sim, multipe, mp_edges, mp_pull, trace: trace_log, mut transfers, .. } =
            ctx;
        transfers.push(self.comm.plan_read_back(4 * csr.num_vertices() as u64));
        // Sharded queries: simulated workload is the multi-PE critical
        // path; boundary-exchange traffic is a transfer class of its own,
        // committed through the same ledger as the DMA records (so it is
        // inside `transfer_seconds` — and thus `query_seconds` — while
        // also reported separately as `exchange_seconds`).
        let mut exchange_seconds = 0.0;
        let sim_stats = match multipe {
            Some((mp, _)) => {
                if crossing_msgs > 0 {
                    let exchange = self.comm.plan_exchange(crossing_msgs);
                    exchange_seconds = exchange.seconds;
                    transfers.push(exchange);
                }
                SimStats {
                    supersteps: mp.supersteps,
                    pull_supersteps: mp_pull,
                    total_edges: mp_edges,
                    cycles: mp.total,
                    launch_seconds: mp.supersteps as f64 * LAUNCH_SECONDS,
                    clock_hz: pipeline.design.pipeline.clock_hz,
                }
            }
            None => sim.finish(),
        };
        let transfer_seconds: f64 = transfers.iter().map(|r| r.seconds).sum();

        if let Some(path) = &opts.trace_path {
            trace_log.write_csv(path)?;
        }

        // Direction split, kept consistent with the *reported* superstep
        // count. The only path where `supersteps` can diverge from the
        // oracle's is the XLA PageRank kernel (its f32 accumulation
        // shifts the DeltaBelow crossing) — and PR runs a uniform
        // direction, so the uniform split is restated over the reported
        // total. Mixed-direction programs (BFS-like) have deterministic
        // integer superstep counts on both paths, where the oracle split
        // is exact.
        let (push_supersteps, pull_supersteps) = if oracle.pull_supersteps == 0 {
            (supersteps, 0)
        } else if oracle.pull_supersteps == oracle.supersteps {
            (0, supersteps)
        } else {
            (oracle.supersteps - oracle.pull_supersteps, oracle.pull_supersteps)
        };

        self.queries_run.fetch_add(1, Ordering::Relaxed);
        let prep_seconds = self.graph.prep_seconds;
        let compile_seconds = design.compile_seconds();
        let deploy_seconds = self.deploy_seconds;
        let sim_exec_seconds = sim_stats.exec_seconds();
        let setup_seconds = prep_seconds + compile_seconds + deploy_seconds;
        let query_seconds = sim_exec_seconds + functional_exec_seconds + transfer_seconds;
        let report = RunReport {
            program: program.name.clone(),
            bound_params: resolved.to_vec(),
            translator: design.kind.label(),
            graph_name: self.graph.name.clone(),
            num_vertices: csr.num_vertices(),
            num_edges: csr.num_edges(),
            prep_seconds,
            compile_seconds,
            deploy_seconds,
            sim_exec_seconds,
            functional_exec_seconds,
            transfer_seconds,
            functional_path,
            supersteps,
            pull_supersteps,
            push_supersteps,
            edges_traversed,
            shards: num_shards,
            auto_shards,
            crossing_msgs,
            exchange_seconds,
            hdl_lines: design.hdl_lines,
            // the report identity: rt = setup + query on every path
            rt_seconds: setup_seconds + query_seconds,
            setup_seconds,
            query_seconds,
            simulated_mteps: sim_stats.mteps(),
            sim: sim_stats,
            oracle_deviation,
        };
        Ok((report, transfers))
    }

    /// Execute one query through a shared reference. Only per-query work
    /// happens here: the software oracle in lockstep with the cycle
    /// simulator, the optional AOT/XLA functional path, and the result
    /// DMA. Safe to call from many threads at once.
    pub fn query(&self, opts: &RunOptions) -> Result<RunReport> {
        let (report, transfers) = self.run_query(opts)?;
        self.comm.commit_guarded(
            &transfers,
            opts.deadline.as_ref(),
            opts.faults.as_deref(),
            faults::exec_token(opts.root, opts.attempt),
            report.supersteps,
        )?;
        Ok(report)
    }

    /// Execute one query (compatibility wrapper over [`Self::query`] —
    /// reports are identical).
    pub fn run(&mut self, opts: &RunOptions) -> Result<RunReport> {
        self.query(opts)
    }

    /// Run a batch of queries (e.g. a 64-source BFS sweep) against the
    /// shared device setup, returning one report per query. Equivalent to
    /// calling [`Self::run`] sequentially — guaranteed by test — while
    /// amortizing graph transport, shell configuration, and preprocessing
    /// across the whole sweep.
    pub fn run_batch(&mut self, queries: &[RunOptions]) -> Result<Vec<RunReport>> {
        queries.iter().map(|opts| self.query(opts)).collect()
    }

    /// Run a batch of queries **concurrently** over `num_workers` OS
    /// threads sharing this binding read-only. Every *modeled* report
    /// field (supersteps, edges, cycles, `sim_exec_seconds`,
    /// `transfer_seconds`, `simulated_mteps`, values) is identical to
    /// [`Self::run_batch`] — concurrency cannot skew the model. The one
    /// exception is `functional_exec_seconds` on the XLA path, which is
    /// *measured* PJRT wall time and so varies run-to-run regardless of
    /// threading. The shared transfer ledger ends up bit-identical: each
    /// worker only *plans* its DMA; records are committed in query order
    /// after the join.
    ///
    /// Errors: the first failing query (by batch order) is returned and
    /// the ledger then matches a sequential run that stopped at that
    /// query. Workers stop claiming new queries once a failure is
    /// observed, but queries already in flight do finish (their effects
    /// are limited to `queries_run` and any per-query trace files).
    pub fn run_batch_parallel(
        &self,
        queries: &[RunOptions],
        num_workers: usize,
    ) -> Result<Vec<RunReport>> {
        // Lease the batch pool from the global budget: per-query shard
        // pools lease from the same ledger, so queries × shards nesting
        // *divides* the machine's cores instead of multiplying. The
        // caller participates as worker 0, so a pool of `workers` spawns
        // only `workers - 1` threads. Budget pressure shrinks the pool,
        // never the reports (each query is modeled identically at any
        // concurrency).
        let want = num_workers.clamp(1, queries.len().max(1));
        let lease = WorkerBudget::global().lease(want);
        let workers = lease.workers();
        if workers == 1 {
            return queries.iter().map(|opts| self.query(opts)).collect();
        }

        let next = AtomicUsize::new(0);
        let failed = AtomicBool::new(false);
        let slots: Vec<Mutex<Option<Result<(RunReport, Vec<TransferRecord>)>>>> =
            queries.iter().map(|_| Mutex::new(None)).collect();
        let work = || loop {
            if failed.load(Ordering::Relaxed) {
                break;
            }
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= queries.len() {
                break;
            }
            let outcome = self.run_query(&queries[i]);
            if outcome.is_err() {
                failed.store(true, Ordering::Relaxed);
            }
            *slots[i].lock().unwrap() = Some(outcome);
        };
        std::thread::scope(|scope| {
            for _ in 1..workers {
                scope.spawn(&work);
            }
            work();
        });
        drop(lease);

        // merge: commit each query's DMA records in batch order so the shared
        // ledger is bit-identical to the sequential path
        let mut reports = Vec::with_capacity(queries.len());
        for (slot, opts) in slots.into_iter().zip(queries) {
            match slot.into_inner().unwrap() {
                Some(outcome) => {
                    let (report, transfers) = outcome?;
                    self.comm.commit_guarded(
                        &transfers,
                        opts.deadline.as_ref(),
                        opts.faults.as_deref(),
                        faults::exec_token(opts.root, opts.attempt),
                        report.supersteps,
                    )?;
                    reports.push(report);
                }
                // Indexes are claimed in strictly increasing order and every
                // claimed query is finished before the scope joins, so an
                // unclaimed (None) slot can only sit *behind* a failed query
                // — and that error returned from the arm above already.
                None => anyhow::bail!("parallel batch aborted before this query ran"),
            }
        }
        Ok(reports)
    }

    /// Run a batch with **per-query fault isolation**: every query
    /// executes behind its own `catch_unwind` fence and returns its own
    /// `Result`, so one poisoned query — a panic, an expired deadline, an
    /// injected fault — never aborts its siblings (unlike
    /// [`Self::run_batch_parallel`], which fail-fasts the whole sweep).
    /// Successful queries' reports and the shared DMA ledger stay
    /// bit-identical to a fault-free sweep: failed queries commit nothing
    /// (the commit guard is all-or-nothing), and successes commit in
    /// batch order exactly as the fail-fast path does.
    pub fn run_batch_isolated(
        &self,
        queries: &[RunOptions],
        num_workers: usize,
    ) -> Vec<Result<RunReport, QueryFailure>> {
        let want = num_workers.clamp(1, queries.len().max(1));
        let lease = WorkerBudget::global().lease(want);
        let workers = lease.workers();

        let next = AtomicUsize::new(0);
        type Slot = Mutex<Option<Result<(RunReport, Vec<TransferRecord>), QueryFailure>>>;
        let slots: Vec<Slot> = queries.iter().map(|_| Mutex::new(None)).collect();
        let work = || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= queries.len() {
                break;
            }
            // The isolation fence: an unwinding query (injected panic at
            // the exec seam, or an organic bug) becomes a typed failure
            // in its own slot. Shard-worker panics already arrive typed
            // (the engine's own fences), so classify() sees them as
            // WorkerPanic errors, not unwinds.
            let outcome = match catch_unwind(AssertUnwindSafe(|| self.run_query(&queries[i]))) {
                Ok(Ok(pair)) => Ok(pair),
                Ok(Err(err)) => Err(QueryFailure::classify(err)),
                Err(payload) => {
                    Err(QueryFailure::Panicked(faults::panic_message(payload.as_ref())))
                }
            };
            *slots[i].lock().unwrap() = Some(outcome);
        };
        if workers == 1 {
            work();
        } else {
            std::thread::scope(|scope| {
                for _ in 1..workers {
                    scope.spawn(&work);
                }
                work();
            });
        }
        drop(lease);

        // merge in batch order: successes commit their DMA behind the
        // commit guard (deadline re-check + commit fault seam); failures
        // leave the shared ledger untouched.
        let mut results = Vec::with_capacity(queries.len());
        for (slot, opts) in slots.into_iter().zip(queries) {
            let outcome = slot
                .into_inner()
                .unwrap()
                .expect("every index is claimed and finished before the scope joins");
            results.push(outcome.and_then(|(report, transfers)| {
                match self.comm.commit_guarded(
                    &transfers,
                    opts.deadline.as_ref(),
                    opts.faults.as_deref(),
                    faults::exec_token(opts.root, opts.attempt),
                    report.supersteps,
                ) {
                    Ok(()) => Ok(report),
                    Err(err) => Err(QueryFailure::classify(err)),
                }
            }));
        }
        results
    }
}

/// Why one query in an isolated sweep ([`BoundPipeline::run_batch_isolated`])
/// failed — typed so the serve layer can map it to the right wire reject
/// and the retry policy can tell transient failures from permanent ones.
#[derive(Debug, Clone)]
pub enum QueryFailure {
    /// The query panicked inside its isolation fence (including a shard
    /// worker's typed [`WorkerPanic`]). Retryable: an injected panic is
    /// keyed to its attempt, so the retry re-runs clean, and an organic
    /// panic just fails typed again.
    Panicked(String),
    /// The wall-clock budget expired (cooperative, with partial
    /// accounting). Never retried — the budget is already spent.
    DeadlineExceeded(DeadlineExceeded),
    /// Any other execution error; `transient` marks injected
    /// exec/transfer faults worth retrying.
    Error {
        message: String,
        transient: bool,
    },
}

impl QueryFailure {
    /// Classify an engine error into the typed failure shape by
    /// downcasting the fault-tolerance error types through `anyhow`.
    pub fn classify(err: anyhow::Error) -> QueryFailure {
        if let Some(de) = err.downcast_ref::<DeadlineExceeded>() {
            return QueryFailure::DeadlineExceeded(de.clone());
        }
        if let Some(wp) = err.downcast_ref::<WorkerPanic>() {
            return QueryFailure::Panicked(wp.to_string());
        }
        let transient = err.downcast_ref::<InjectedFault>().is_some_and(|f| f.transient());
        QueryFailure::Error { message: format!("{err:#}"), transient }
    }

    /// Is a retry worth attempting?
    pub fn transient(&self) -> bool {
        match self {
            QueryFailure::Panicked(_) => true,
            QueryFailure::DeadlineExceeded(_) => false,
            QueryFailure::Error { transient, .. } => *transient,
        }
    }
}

impl fmt::Display for QueryFailure {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            QueryFailure::Panicked(msg) => write!(f, "query panicked: {msg}"),
            QueryFailure::DeadlineExceeded(de) => de.fmt(f),
            QueryFailure::Error { message, .. } => f.write_str(message),
        }
    }
}

impl std::error::Error for QueryFailure {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::engine::session::{Session, SessionConfig};
    use crate::graph::generate;
    use crate::prep::prepared::PrepOptions;

    fn session() -> Session {
        Session::new(SessionConfig { use_xla: false, ..Default::default() })
    }

    #[test]
    fn second_query_reuses_setup() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(200, 2_000, 7);
        let mut bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let r1 = bound.run(&RunOptions::from_root(0)).unwrap();
        let r2 = bound.run(&RunOptions::from_root(0)).unwrap();
        assert_eq!(bound.queries_run(), 2);
        // one-time periods are identical (paid once, reported unchanged)
        assert_eq!(r1.prep_seconds, r2.prep_seconds);
        assert_eq!(r1.deploy_seconds, r2.deploy_seconds);
        assert_eq!(r1.setup_seconds, r2.setup_seconds);
        // deterministic query results
        assert_eq!(r1.supersteps, r2.supersteps);
        assert_eq!(r1.edges_traversed, r2.edges_traversed);
        assert_eq!(r1.simulated_mteps, r2.simulated_mteps);
        // the setup/query split decomposes rt
        assert!((r1.setup_seconds + r1.query_seconds - r1.rt_seconds).abs() < 1e-12);
    }

    #[test]
    fn different_roots_change_the_query_not_the_setup() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::grid2d(16, 16, 3);
        let mut bound = c.load(&g, PrepOptions::named("grid")).unwrap();
        let r_corner = bound.run(&RunOptions::from_root(0)).unwrap();
        let r_center = bound.run(&RunOptions::from_root(8 * 16 + 8)).unwrap();
        assert_eq!(r_corner.setup_seconds, r_center.setup_seconds);
        // grid BFS from the corner needs more supersteps than from the
        // center (eccentricity 30 vs ~16)
        assert!(r_corner.supersteps > r_center.supersteps);
    }

    #[test]
    fn queries_share_the_binding_without_mut() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(150, 1_200, 3);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        // no `mut`: the per-query path borrows the binding immutably
        let r1 = bound.query(&RunOptions::from_root(0)).unwrap();
        let r2 = bound.query(&RunOptions::from_root(1)).unwrap();
        assert_eq!(bound.queries_run(), 2);
        assert_eq!(r1.setup_seconds, r2.setup_seconds);
    }

    #[test]
    fn reports_record_the_direction_split() {
        let s = session();
        let g = generate::rmat(10, 80_000, 0.57, 0.19, 0.19, 3);
        // a dense rmat BFS has a pull phase in the middle and push phases
        // at the ends
        let bfs = s.compile(&algorithms::bfs()).unwrap();
        let bound = bfs.load(&g, PrepOptions::named("rmat")).unwrap();
        let r = bound.query(&RunOptions::from_root(0)).unwrap();
        assert_eq!(r.push_supersteps + r.pull_supersteps, r.supersteps);
        assert!(r.pull_supersteps > 0, "dense-middle BFS must pull");
        assert!(r.push_supersteps > 0, "sparse BFS phases must push");
        assert_eq!(r.sim.pull_supersteps, r.pull_supersteps, "simulator saw the same choices");
        // every PageRank superstep is dense: all pull (loose tolerance —
        // this test is about direction accounting, not convergence depth)
        let pr = s.compile(&algorithms::pagerank()).unwrap();
        let bound = pr.load(&g, PrepOptions::named("rmat")).unwrap();
        let r = bound.query(&RunOptions::default().bind("tolerance", 1e-3)).unwrap();
        assert_eq!(r.pull_supersteps, r.supersteps);
        assert_eq!(r.push_supersteps, 0);
    }

    #[test]
    fn iteration_cap_hit_aborts_the_query() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        // chain BFS from 0 needs ~n supersteps: a cap of 3 must trip
        let g = generate::chain(64);
        let bound = c.load(&g, PrepOptions::named("chain")).unwrap();
        let err = bound.query(&RunOptions::from_root(0).with_max_supersteps(3)).unwrap_err();
        assert!(err.to_string().contains("iteration cap 3 hit"), "expected cap error: {err}");
        // the binding stays usable; an uncapped query still converges
        let ok = bound.query(&RunOptions::from_root(0)).unwrap();
        assert!(ok.supersteps > 3);
    }

    #[test]
    fn non_converging_program_errors_without_an_explicit_cap() {
        // delta < -1 is unsatisfiable: PageRank exhausts its internal
        // bound without converging. The default query path must turn that
        // into an error, not return truncated values.
        let s = session();
        let c = s.compile(&algorithms::pagerank()).unwrap();
        let g = generate::erdos_renyi(60, 400, 2);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let err = bound.query(&RunOptions::default().bind("tolerance", -1.0)).unwrap_err();
        assert!(err.to_string().contains("iteration cap"), "got: {err}");
        assert!(err.to_string().contains("did not converge"), "got: {err}");
        // the same binding sweeps per query: a sane tolerance succeeds on
        // the very same binding with zero recompiles
        let ok = bound.query(&RunOptions::default()).unwrap();
        assert!(ok.supersteps > 0);
        assert_eq!(ok.bound_params[0], ("damping".to_string(), 0.85));
    }

    #[test]
    fn unknown_param_binding_is_rejected_naming_the_signature() {
        let s = session();
        let c = s.compile(&algorithms::pagerank()).unwrap();
        let g = generate::erdos_renyi(40, 200, 1);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let err = bound.query(&RunOptions::default().bind("dampng", 0.9)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown parameter \"dampng\""), "{msg}");
        assert!(msg.contains("damping, tolerance"), "typo help must list the signature: {msg}");
    }

    #[test]
    fn parallel_batch_edge_cases_match_sequential() {
        // PR 2 inherited edge cases: empty batch, one worker, more
        // workers than queries — all report-identical to `run_batch`.
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(120, 900, 9);
        let mut seq = c.load(&g, PrepOptions::named("er")).unwrap();
        let par = c.load(&g, PrepOptions::named("er")).unwrap();

        // empty query list: Ok(vec![]) on both paths, ledgers untouched
        assert!(seq.run_batch(&[]).unwrap().is_empty());
        assert!(par.run_batch_parallel(&[], 4).unwrap().is_empty());
        assert_eq!(par.queries_run(), 0);

        let queries: Vec<RunOptions> = (0..3).map(RunOptions::from_root).collect();
        let sequential = seq.run_batch(&queries).unwrap();
        for workers in [1, 8] {
            let parallel = par.run_batch_parallel(&queries, workers).unwrap();
            assert_eq!(parallel.len(), sequential.len(), "workers={workers}");
            for (p, q) in parallel.iter().zip(&sequential) {
                assert_eq!(p.supersteps, q.supersteps, "workers={workers}");
                assert_eq!(p.edges_traversed, q.edges_traversed);
                assert_eq!(p.query_seconds.to_bits(), q.query_seconds.to_bits());
                assert_eq!(p.sim.cycles.total(), q.sim.cycles.total());
            }
        }
    }

    #[test]
    fn parallel_batch_sweeps_parameters_not_just_roots() {
        let s = session();
        let c = s.compile(&algorithms::pagerank()).unwrap();
        let g = generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 5);
        let mut seq = c.load(&g, PrepOptions::named("rmat")).unwrap();
        let par = c.load(&g, PrepOptions::named("rmat")).unwrap();
        let queries: Vec<RunOptions> = (1..=4)
            .map(|i| RunOptions::default().bind("damping", 0.2 * i as f64))
            .collect();
        let sequential = seq.run_batch(&queries).unwrap();
        let parallel = par.run_batch_parallel(&queries, 2).unwrap();
        for (p, q) in parallel.iter().zip(&sequential) {
            assert_eq!(p.bound_params, q.bound_params);
            assert_eq!(p.supersteps, q.supersteps);
            assert_eq!(p.query_seconds.to_bits(), q.query_seconds.to_bits());
        }
        // damping actually changes the computation
        assert_ne!(parallel[0].supersteps, parallel[3].supersteps);
    }

    #[test]
    fn read_back_dma_is_reported_and_in_query_seconds() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(500, 4_000, 5);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let before = bound.comm().transfer_seconds();
        let r = bound.query(&RunOptions::from_root(0)).unwrap();
        // read-back of 4 * num_vertices bytes takes nonzero modeled time
        assert!(r.transfer_seconds > 0.0, "read-back DMA must be accounted");
        let expected = bound.comm().plan_read_back(4 * 500).seconds;
        assert_eq!(r.transfer_seconds.to_bits(), expected.to_bits());
        // it is part of the per-query cost and of the shared ledger
        assert!(r.query_seconds >= r.sim_exec_seconds + r.transfer_seconds);
        assert!((bound.comm().transfer_seconds() - before - expected).abs() < 1e-15);
    }

    #[test]
    fn parallel_batch_matches_sequential_and_merges_accounting() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(10, 40_000, 0.57, 0.19, 0.19, 17);
        let n = g.num_vertices as u32;
        let queries: Vec<RunOptions> =
            (0..8u32).map(|i| RunOptions::from_root((i * 4_099) % n)).collect();

        let mut seq_bound = c.load(&g, PrepOptions::named("rmat11")).unwrap();
        let sequential = seq_bound.run_batch(&queries).unwrap();

        let par_bound = c.load(&g, PrepOptions::named("rmat11")).unwrap();
        let parallel = par_bound.run_batch_parallel(&queries, 4).unwrap();

        assert_eq!(parallel.len(), sequential.len());
        for (p, q) in parallel.iter().zip(&sequential) {
            assert_eq!(p.supersteps, q.supersteps);
            assert_eq!(p.edges_traversed, q.edges_traversed);
            assert_eq!(p.simulated_mteps.to_bits(), q.simulated_mteps.to_bits());
            assert_eq!(p.sim.cycles.total(), q.sim.cycles.total());
            assert_eq!(p.transfer_seconds.to_bits(), q.transfer_seconds.to_bits());
            // query cost is fully modeled, so it cannot depend on threading
            // (rt/setup include measured prep wall time, which differs
            // between the two independent `load`s above by construction)
            assert_eq!(p.query_seconds.to_bits(), q.query_seconds.to_bits());
        }
        // merged ledger totals are bit-identical to the sequential path
        assert_eq!(par_bound.comm().bytes_moved(), seq_bound.comm().bytes_moved());
        assert_eq!(
            par_bound.comm().transfer_seconds().to_bits(),
            seq_bound.comm().transfer_seconds().to_bits()
        );
        assert_eq!(par_bound.queries_run(), queries.len() as u64);
    }

    #[test]
    fn partitioned_binding_runs_sharded_and_reports_exchange() {
        use crate::prep::partition::PartitionStrategy;
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(10, 20_000, 0.57, 0.19, 0.19, 21);
        let mono = c.load(&g, PrepOptions::named("rmat")).unwrap();
        let shard = c
            .load(
                &g,
                PrepOptions::named("rmat").with_partition(4, PartitionStrategy::BfsGrow),
            )
            .unwrap();
        let rm = mono.query(&RunOptions::from_root(0)).unwrap();
        let rs = shard.query(&RunOptions::from_root(0)).unwrap();
        // monolithic reports stay shard-free
        assert_eq!(rm.shards, 0);
        assert_eq!(rm.crossing_msgs, 0);
        assert_eq!(rm.exchange_seconds, 0.0);
        // the sharded run converges identically (per-shard direction
        // choices never change values or the superstep count)...
        assert_eq!(rs.supersteps, rm.supersteps);
        // ...and pinned push-only, it traverses exactly the same edges
        let push = RunOptions::from_root(0).with_direction(gas::DirectionPolicy::PushOnly);
        let pm = mono.query(&push).unwrap();
        let ps = shard.query(&push).unwrap();
        assert_eq!(ps.supersteps, pm.supersteps);
        assert_eq!(ps.edges_traversed, pm.edges_traversed);
        // ...with the sharding visible in the report
        assert_eq!(rs.shards, 4);
        assert!(rs.crossing_msgs > 0, "a 4-way rmat cut must cross");
        assert!(rs.exchange_seconds > 0.0);
        // exchange is priced inside transfer_seconds alongside read-back
        let read_back = shard.comm().plan_read_back(4 * rs.num_vertices as u64).seconds;
        assert!(
            (rs.transfer_seconds - (read_back + rs.exchange_seconds)).abs() < 1e-15,
            "transfer {} != read_back {} + exchange {}",
            rs.transfer_seconds,
            read_back,
            rs.exchange_seconds
        );
        // the simulated workload is the multi-PE model over real traces
        assert_eq!(rs.sim.supersteps, rs.supersteps);
        assert_eq!(rs.sim.total_edges, rs.edges_traversed);
        assert_eq!(rs.sim.pull_supersteps, rs.pull_supersteps);
        assert!(rs.sim.cycles.total() > 0);
        // the report identity holds on the sharded path too
        assert!((rs.setup_seconds + rs.query_seconds - rs.rt_seconds).abs() < 1e-12);
        assert!(
            (rs.query_seconds
                - (rs.sim_exec_seconds + rs.functional_exec_seconds + rs.transfer_seconds))
                .abs()
                < 1e-15
        );
    }

    #[test]
    fn shard_worker_count_does_not_change_the_report() {
        use crate::prep::partition::PartitionStrategy;
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 5);
        let bound = c
            .load(&g, PrepOptions::named("rmat").with_partition(4, PartitionStrategy::Hash))
            .unwrap();
        let base = bound.query(&RunOptions::from_root(0)).unwrap();
        for workers in [1, 2, 7] {
            let r = bound
                .query(&RunOptions::from_root(0).with_shard_workers(workers))
                .unwrap();
            assert_eq!(r.supersteps, base.supersteps, "workers={workers}");
            assert_eq!(r.edges_traversed, base.edges_traversed);
            assert_eq!(r.crossing_msgs, base.crossing_msgs);
            assert_eq!(r.sim.cycles.total(), base.sim.cycles.total());
            assert_eq!(r.query_seconds.to_bits(), base.query_seconds.to_bits());
        }
    }

    #[test]
    fn auto_sharded_query_keeps_monolithic_reporting() {
        // An un-partitioned binding with pinned auto-shards runs the
        // sharded engine but reports like the monolithic sweep: threads
        // are an execution detail, not a deployment shape.
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(10, 40_000, 0.57, 0.19, 0.19, 17);
        let mono = c.load(&g, PrepOptions::named("rmat").with_auto_shards(1)).unwrap();
        let auto = c.load(&g, PrepOptions::named("rmat").with_auto_shards(4)).unwrap();
        let rm = mono.query(&RunOptions::from_root(0)).unwrap();
        let ra = auto.query(&RunOptions::from_root(0)).unwrap();
        // sharding is visible in its own field, not the user-shard one
        assert_eq!(rm.auto_shards, 0);
        assert_eq!(ra.auto_shards, 4);
        assert_eq!(ra.shards, 0, "auto-shards are not deployment shards");
        assert_eq!(ra.crossing_msgs, 0);
        assert_eq!(ra.exchange_seconds, 0.0);
        // values/supersteps are the sharded-engine exactness contract
        assert_eq!(ra.supersteps, rm.supersteps);
        // the single-PE simulator sees one merged batch per superstep
        assert_eq!(ra.sim.supersteps, ra.supersteps);
        assert_eq!(ra.sim.total_edges, ra.edges_traversed);
        assert_eq!(ra.sim.pull_supersteps, ra.pull_supersteps);
        // no exchange billing: the read-back is the only transfer
        let read_back = auto.comm().plan_read_back(4 * ra.num_vertices as u64).seconds;
        assert_eq!(ra.transfer_seconds.to_bits(), read_back.to_bits());
        // push-only pinned traverses exactly the monolithic edges
        let push = RunOptions::from_root(0).with_direction(gas::DirectionPolicy::PushOnly);
        let pm = mono.query(&push).unwrap();
        let pa = auto.query(&push).unwrap();
        assert_eq!(pa.supersteps, pm.supersteps);
        assert_eq!(pa.edges_traversed, pm.edges_traversed);
        assert_eq!(pa.pull_supersteps, 0);
        // worker squeeze never changes an auto-sharded report
        let one = auto.query(&RunOptions::from_root(0).with_shard_workers(1)).unwrap();
        assert_eq!(one.supersteps, ra.supersteps);
        assert_eq!(one.edges_traversed, ra.edges_traversed);
        assert_eq!(one.query_seconds.to_bits(), ra.query_seconds.to_bits());
    }

    #[test]
    fn global_budget_caps_nested_thread_fanout() {
        // queries × shards nesting leases every thread from one ledger:
        // the peak lease can never exceed the budget's extra permits, no
        // matter how the batch and shard pools stack.
        use crate::prep::partition::PartitionStrategy;
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 5);
        let bound = c
            .load(&g, PrepOptions::named("rmat").with_partition(4, PartitionStrategy::Hash))
            .unwrap();
        let queries: Vec<RunOptions> = (0..6).map(RunOptions::from_root).collect();
        let reports = bound.run_batch_parallel(&queries, 16).unwrap();
        assert_eq!(reports.len(), 6);
        let budget = WorkerBudget::global();
        // live threads = 1 root + leased extras ≤ the budgeted total
        assert!(
            budget.peak_leased() < budget.total_workers(),
            "peak {} extras exceeds a {}-worker budget",
            budget.peak_leased(),
            budget.total_workers()
        );
    }

    #[test]
    fn expired_deadline_fails_typed_before_any_work() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(200, 2_000, 7);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let before = bound.comm().transfer_seconds().to_bits();
        let dead = Deadline::in_duration(std::time::Duration::ZERO);
        let err = bound.query(&RunOptions::from_root(0).with_deadline(dead)).unwrap_err();
        let de = err.downcast_ref::<DeadlineExceeded>().expect("typed DeadlineExceeded");
        assert_eq!(de.supersteps_completed, 0, "expired before any superstep");
        assert_eq!(bound.comm().transfer_seconds().to_bits(), before, "no DMA committed");
        // the binding stays usable and an unbudgeted query still succeeds
        let ok = bound
            .query(
                &RunOptions::from_root(0)
                    .with_deadline(Deadline::in_duration(std::time::Duration::from_secs(3600))),
            )
            .unwrap();
        assert!(ok.supersteps > 0);
    }

    #[test]
    fn isolated_sweep_contains_one_poisoned_query() {
        // Satellite: one injected panic in a sweep fails typed while every
        // sibling's report — and the shared DMA ledger — stays
        // bit-identical to the fault-free run.
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 11);
        let clean_bound = c.load(&g, PrepOptions::named("rmat")).unwrap();
        let chaos_bound = c.load(&g, PrepOptions::named("rmat")).unwrap();
        let plain: Vec<RunOptions> = (0..6).map(RunOptions::from_root).collect();
        let clean = clean_bound.run_batch_parallel(&plain, 3).unwrap();

        // panic@exec#3 fires on root 3's first attempt only
        let plan = Arc::new(FaultPlan::parse("panic@exec#3").unwrap());
        let queries: Vec<RunOptions> =
            (0..6).map(|r| RunOptions::from_root(r).with_faults(plan.clone())).collect();
        let outcomes = chaos_bound.run_batch_isolated(&queries, 3);
        assert_eq!(outcomes.len(), 6);
        for (i, outcome) in outcomes.iter().enumerate() {
            if i == 3 {
                let failure = outcome.as_ref().unwrap_err();
                assert!(matches!(failure, QueryFailure::Panicked(_)), "{failure}");
                assert!(failure.transient(), "panics earn a retry");
                assert!(failure.to_string().contains("injected fault: panic@exec"));
            } else {
                let r = outcome.as_ref().unwrap();
                assert_eq!(r.supersteps, clean[i].supersteps, "query {i}");
                assert_eq!(r.edges_traversed, clean[i].edges_traversed);
                assert_eq!(r.query_seconds.to_bits(), clean[i].query_seconds.to_bits());
                assert_eq!(r.simulated_mteps.to_bits(), clean[i].simulated_mteps.to_bits());
            }
        }
        assert_eq!(plan.injected_total(), 1);

        // the retry (attempt 1) misses the attempt-keyed rule, re-runs
        // clean, and lands the poisoned query's report bit-identical too —
        // after which the ledgers of both bindings agree exactly
        let retried = chaos_bound.run_batch_isolated(&[queries[3].clone().with_attempt(1)], 1);
        let r = retried[0].as_ref().unwrap();
        assert_eq!(r.query_seconds.to_bits(), clean[3].query_seconds.to_bits());
        assert_eq!(chaos_bound.comm().bytes_moved(), clean_bound.comm().bytes_moved());
        assert_eq!(
            chaos_bound.comm().transfer_seconds().to_bits(),
            clean_bound.comm().transfer_seconds().to_bits()
        );
    }

    #[test]
    fn injected_transfer_error_is_transient_and_commits_nothing() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::erdos_renyi(150, 1_200, 3);
        let bound = c.load(&g, PrepOptions::named("er")).unwrap();
        let before = bound.comm().bytes_moved();
        let plan = Arc::new(FaultPlan::parse("transfer_error@commit#5").unwrap());
        let outcomes = bound
            .run_batch_isolated(&[RunOptions::from_root(5).with_faults(plan.clone())], 1);
        match outcomes[0].as_ref().unwrap_err() {
            QueryFailure::Error { transient, message } => {
                assert!(*transient, "injected transfer errors are retryable");
                assert!(message.contains("transfer_error@commit"), "{message}");
            }
            other => panic!("expected transient Error, got {other}"),
        }
        assert_eq!(bound.comm().bytes_moved(), before, "failed commit must be all-or-nothing");
        // retry (attempt 1) commits normally
        let retried = bound.run_batch_isolated(
            &[RunOptions::from_root(5).with_faults(plan).with_attempt(1)],
            1,
        );
        assert!(retried[0].is_ok());
        assert!(bound.comm().bytes_moved() > before);
    }

    #[test]
    fn parallel_batch_propagates_cap_errors() {
        let s = session();
        let c = s.compile(&algorithms::bfs()).unwrap();
        let g = generate::chain(64);
        let bound = c.load(&g, PrepOptions::named("chain")).unwrap();
        let mut queries = vec![RunOptions::from_root(0); 6];
        queries[3] = RunOptions::from_root(0).with_max_supersteps(2);
        let err = bound.run_batch_parallel(&queries, 3).unwrap_err();
        assert!(err.to_string().contains("iteration cap 2 hit"), "{err}");
    }
}
