//! **Session** — the process-wide entry point of the compile-once /
//! run-many lifecycle:
//!
//! ```text
//! Session::new(cfg) ──compile(&program)──▶ CompiledPipeline
//!                                              │ load(&graph, PrepOptions)
//!                                              ▼
//!                                         BoundPipeline ──run(RunOptions)──▶ RunReport
//! ```
//!
//! The session owns what is paid once per process: the PJRT artifact
//! registry (opened lazily, shared by every pipeline), the device model,
//! and the default translator. `compile` pays the per-program costs once —
//! validation, lowering, scheduling, code generation, the modeled
//! synthesis + bitstream flash, and the XLA artifact-registry lookup — so
//! that queries only pay the per-query superstep work.
//!
//! Downstream of `compile`, the binding serves queries through `&self`
//! (scheduler admission happens once at `load`/`bind`; per-query state
//! lives in [`super::bound::QueryContext`]), so one compiled design + one
//! prepared graph can serve a concurrent sweep via
//! [`super::bound::BoundPipeline::run_batch_parallel`].

use std::cell::OnceCell;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use crate::accel::device::DeviceModel;
use crate::dsl::program::GasProgram;
use crate::runtime::KernelRegistry;
use crate::translator::Translator;

use super::compiled::CompiledPipeline;
use super::executor::FLASH_SECONDS;

/// Process-wide configuration: the knobs that outlive any single program
/// or graph.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Target device for admission checks and the cycle simulator.
    pub device: DeviceModel,
    /// Default translation flow (override per program with
    /// [`Session::compile_with`]).
    pub translator: Translator,
    /// Drive the AOT/XLA kernels when a program has one. When the artifact
    /// registry cannot be opened (artifacts not built, PJRT stubbed out),
    /// runs fall back to the software oracle instead of failing.
    pub use_xla: bool,
    /// Artifact directory override (`None` = `$JGRAPH_ARTIFACTS` or the
    /// workspace `artifacts/` lookup).
    pub artifact_dir: Option<PathBuf>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            device: DeviceModel::u200(),
            translator: Translator::jgraph(),
            use_xla: true,
            artifact_dir: None,
        }
    }
}

/// Typed compile-stage errors: what can go wrong between a DSL program and
/// a deployable [`CompiledPipeline`]. Each variant names the offending
/// program so multi-program services can attribute failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// The program failed DSL validation (see [`crate::dsl::validate`]).
    InvalidProgram { program: String, reason: String },
    /// Lowering/code generation failed.
    Translation { program: String, reason: String },
    /// The translated design does not fit the session's device.
    DoesNotFit { program: String, translator: &'static str, device: &'static str },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::InvalidProgram { program, reason } => {
                write!(f, "program {program:?} failed validation: {reason}")
            }
            CompileError::Translation { program, reason } => {
                write!(f, "translating program {program:?} failed: {reason}")
            }
            CompileError::DoesNotFit { program, translator, device } => {
                write!(f, "design {program:?} via {translator} does not fit {device}")
            }
        }
    }
}

impl std::error::Error for CompileError {}

/// The process-wide state of the lifecycle. Create one per process (or
/// per tenant) and reuse it: registries and manifests are opened once.
pub struct Session {
    config: SessionConfig,
    /// Lazily-opened artifact registry; `None` inside means "tried and
    /// unavailable" (recorded once, not retried per compile).
    registry: OnceCell<Option<Arc<KernelRegistry>>>,
    /// Injected registry (tests/benches share one across sessions).
    injected: Option<Arc<KernelRegistry>>,
}

impl Session {
    pub fn new(config: SessionConfig) -> Self {
        Self { config, registry: OnceCell::new(), injected: None }
    }

    /// Inject a shared registry (benches/tests); otherwise opened lazily
    /// on the first compile of a canonical program.
    pub fn with_registry(mut self, registry: Arc<KernelRegistry>) -> Self {
        self.injected = Some(registry);
        self
    }

    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    pub fn device(&self) -> &DeviceModel {
        &self.config.device
    }

    /// The artifact registry, opened at most once per session. `None` when
    /// XLA is disabled or the artifacts are unavailable.
    pub(crate) fn registry(&self) -> Option<Arc<KernelRegistry>> {
        if let Some(r) = &self.injected {
            return Some(r.clone());
        }
        if !self.config.use_xla {
            return None;
        }
        self.registry
            .get_or_init(|| {
                let opened = match &self.config.artifact_dir {
                    Some(dir) => KernelRegistry::open(dir),
                    None => KernelRegistry::open_default(),
                };
                opened.ok().map(Arc::new)
            })
            .clone()
    }

    /// Compile a program with the session's default translator. All
    /// one-time program costs happen here; the result is reusable across
    /// graphs and queries.
    pub fn compile(&self, program: &GasProgram) -> Result<CompiledPipeline, CompileError> {
        self.compile_with(self.config.translator, program)
    }

    /// Compile with an explicit translator (flow and parallelism plan).
    pub fn compile_with(
        &self,
        translator: Translator,
        program: &GasProgram,
    ) -> Result<CompiledPipeline, CompileError> {
        let t0 = Instant::now();
        crate::dsl::validate::check(program).map_err(|e| CompileError::InvalidProgram {
            program: program.name.clone(),
            reason: e.to_string(),
        })?;
        let design = translator.translate(program).map_err(|e| CompileError::Translation {
            program: program.name.clone(),
            reason: e.to_string(),
        })?;
        if !design.fits(&self.config.device) {
            return Err(CompileError::DoesNotFit {
                program: program.name.clone(),
                translator: design.kind.label(),
                device: self.config.device.name,
            });
        }
        // XLA artifact lookup happens once, at compile time: the registry
        // (and its manifest) is resolved here and pinned into the pipeline.
        let registry =
            if self.config.use_xla && program.kind.is_some() { self.registry() } else { None };
        Ok(CompiledPipeline::from_parts(
            program.clone(),
            design,
            self.config.device.clone(),
            registry,
            FLASH_SECONDS,
            t0.elapsed().as_secs_f64(),
        ))
    }
}

impl Default for Session {
    fn default() -> Self {
        Self::new(SessionConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::dsl::program::Writeback;
    use crate::sched::ParallelismPlan;

    #[test]
    fn compile_succeeds_for_canonical_algorithms() {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        for p in algorithms::all() {
            let c = session.compile(&p).unwrap();
            assert_eq!(c.program().name, p.name);
            assert!(c.compile_seconds() > 0.0);
        }
    }

    #[test]
    fn invalid_program_is_a_typed_error() {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        let mut evil = algorithms::bfs();
        evil.reduce = crate::dsl::program::ReduceOp::Sum;
        evil.writeback = Writeback::IfUnvisited;
        match session.compile(&evil) {
            Err(CompileError::InvalidProgram { program, reason }) => {
                assert_eq!(program, "bfs");
                assert!(reason.contains("not idempotent"), "{reason}");
            }
            other => panic!("expected InvalidProgram, got {other:?}"),
        }
    }

    #[test]
    fn oversized_plan_is_does_not_fit() {
        let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
        let translator = Translator::jgraph().with_plan(ParallelismPlan::new(512, 8));
        let err = session.compile_with(translator, &algorithms::bfs()).unwrap_err();
        assert!(matches!(err, CompileError::DoesNotFit { .. }));
        assert!(err.to_string().contains("does not fit"), "{err}");
    }

    #[test]
    fn registry_is_resolved_at_most_once() {
        let session = Session::new(SessionConfig {
            use_xla: true,
            artifact_dir: Some(std::path::PathBuf::from("/nonexistent/jgraph-artifacts")),
            ..Default::default()
        });
        // both compiles observe the same (cached) lookup failure
        assert!(session.registry().is_none());
        assert!(session.registry().is_none());
        let c = session.compile(&algorithms::bfs()).unwrap();
        assert!(!c.has_xla(), "no artifacts -> software fallback");
    }
}
