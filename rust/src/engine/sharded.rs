//! Sharded GAS execution — "k shards, one superstep". Each superstep
//! fans out across the shards of a [`ShardedGraph`] on scoped worker
//! threads (per-shard push/pull decision, Graphitron-style), then a
//! deterministic boundary-exchange merge commits results on the main
//! thread.
//!
//! ## Exactness contract
//!
//! `values`, `supersteps`, and `converged` are **bit-identical** to the
//! monolithic engine ([`super::gas`]) for any program, shard count,
//! worker count, and [`crate::prep::partition::PartitionStrategy`]. The
//! load-bearing fact is destination ownership (see
//! [`crate::prep::shard`]): every message destined to vertex `v` is
//! produced and reduced inside `v`'s owner shard, in the monolithic
//! delivery order, into a private accumulator. The cross-shard merge
//! only writes disjoint vertex sets back, so merge order cannot
//! reassociate any reduction.
//!
//! ## Merge discipline
//!
//! The merge order is still pinned by the program's
//! [`ParallelSafety`](crate::analysis::ParallelSafety) certificate:
//! `BitExact` (idempotent, order-insensitive) programs commit shards in
//! worker *completion* order — first shard done, first merged — while
//! `OrderSensitive`/`Racy` programs commit in fixed shard-major order.
//! Both produce identical bits here (disjoint writebacks); the pin keeps
//! the committed discipline aligned with the certificate so downstream
//! consumers (multi-PE placement, future cross-device exchange where
//! merges *could* touch shared rows) inherit a safe default.
//!
//! Like the monolithic engine, `edges_traversed`, traces, and the
//! direction split describe the work actually performed — per-shard
//! direction choices make those legitimately different from both the
//! monolithic engine and other shard counts. `crossing_msgs` counts the
//! boundary messages (edges whose source value lives in another shard)
//! actually traversed, the volume the comm layer ledgers as exchange.

use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Mutex, PoisonError};

use crate::analysis::ParallelSafety;
use crate::sched::faults::{panic_message, shard_token, FaultPlan, InjectedFault, Seam, WorkerPanic};
use crate::dsl::apply::{ApplyEnv, CompiledApply};
use crate::dsl::params::ParamSet;
use crate::dsl::program::{
    Convergence, Direction, FrontierPolicy, GasProgram, InitPolicy, ReduceOp, Writeback,
};
use crate::graph::VertexId;
use crate::prep::shard::{Shard, ShardedGraph};

use super::frontier::Frontier;
use super::gas::{
    eval_msg, init_values, reduce_combine, reduce_identity, Crossover, DirectionPolicy,
    EngineGraph, GasResult,
};

/// Frontier size (in vertices) below which a superstep skips thread
/// dispatch and sweeps the shards serially on the calling thread: for a
/// sparse frontier the scoped-spawn cost exceeds the scatter itself.
/// Purely a latency gate — serial and threaded supersteps produce the
/// same scratch, so values and traces are unaffected.
pub(crate) const SHARD_DISPATCH_MIN_FRONTIER: usize = 1024;

/// Per-superstep trace of a sharded run — the sharded analogue of
/// [`super::gas::SuperstepTrace`], carrying one destination stream per
/// shard so the multi-PE simulator can charge each shard's traffic to
/// its own PE.
pub struct ShardedSuperstepTrace<'a> {
    pub index: u32,
    /// Destination stream of every shard this superstep (push sub-row
    /// scatter order or CSC ascending runs, per that shard's direction).
    pub shard_dsts: &'a [&'a [u32]],
    /// Boundary messages each shard traversed this superstep (edges with
    /// a foreign source).
    pub shard_crossing: &'a [u64],
    /// Direction each shard ran this superstep.
    pub directions: &'a [Direction],
    /// Rows opened across all shards (active push rows + swept pull rows).
    pub active_rows: u64,
}

/// Result of a sharded run: the monolithic-identical [`GasResult`] plus
/// the total boundary-exchange volume for the comm ledger.
pub struct ShardedRun {
    pub result: GasResult,
    /// Total boundary messages traversed (summed over shards and
    /// supersteps) — the exchange volume `CommManager::plan_exchange`
    /// prices.
    pub crossing_msgs: u64,
}

/// Sharded analogue of [`super::gas::run_with_policy`]: execute
/// `program` over the shards of `sg` with up to `workers` threads.
/// `g` supplies the monolithic arrays the serial parts still read
/// (init sizing, PageRank out-degrees); `sg` must be built from the
/// same graph.
pub fn run_sharded(
    program: &GasProgram,
    g: &EngineGraph<'_>,
    sg: &ShardedGraph,
    root: VertexId,
    policy: DirectionPolicy,
    workers: usize,
    observer: impl FnMut(&ShardedSuperstepTrace<'_>) -> Result<()>,
) -> Result<ShardedRun> {
    run_sharded_with_faults(program, g, sg, root, policy, workers, None, observer)
}

/// [`run_sharded`] with an optional fault-injection plan: every shard
/// dispatch (serial or threaded) runs behind a panic-isolation fence
/// that first trips the [`Seam::Shard`] seam. A worker panic — injected
/// or organic — surfaces as a typed [`WorkerPanic`] error for the whole
/// query (partial shard scratch can never be merged bit-identically)
/// instead of tearing down the process.
#[allow(clippy::too_many_arguments)]
pub fn run_sharded_with_faults(
    program: &GasProgram,
    g: &EngineGraph<'_>,
    sg: &ShardedGraph,
    root: VertexId,
    policy: DirectionPolicy,
    workers: usize,
    faults: Option<&FaultPlan>,
    mut observer: impl FnMut(&ShardedSuperstepTrace<'_>) -> Result<()>,
) -> Result<ShardedRun> {
    let owned;
    let program = if program.has_runtime_params() {
        owned = program.instantiate(&ParamSet::new())?;
        &owned
    } else {
        program
    };
    let facts = crate::analysis::analyze(program);
    if facts.damped_iteration {
        return run_pagerank_sharded(program, g, sg, root, policy, workers, faults, &mut observer);
    }
    run_generic_sharded(program, &facts, g, sg, root, policy, workers, faults, &mut observer)
}

/// Run one shard's share of a superstep behind the panic-isolation
/// fence: trip the shard fault seam, then do the work. A panic inside
/// (injected or organic) is caught and rendered as a typed
/// [`WorkerPanic`]; an injected error fault comes back typed as
/// [`InjectedFault`]. Used identically on worker threads and on the
/// serial fallback path, so the failure shape does not depend on the
/// dispatch gate.
fn fence_shard(
    s: usize,
    root: VertexId,
    faults: Option<&FaultPlan>,
    work: impl FnOnce(),
) -> Result<()> {
    let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<(), InjectedFault> {
        if let Some(plan) = faults {
            plan.trip(Seam::Shard, shard_token(root, s))?;
        }
        work();
        Ok(())
    }));
    match outcome {
        Ok(Ok(())) => Ok(()),
        Ok(Err(injected)) => Err(injected.into()),
        Err(payload) => {
            Err(WorkerPanic { shard: s, message: panic_message(payload.as_ref()) }.into())
        }
    }
}

/// First failure wins; later workers' failures are dropped (the query is
/// already lost, and first-wins keeps the reported cause stable).
fn record_failure(slot: &Mutex<Option<anyhow::Error>>, err: anyhow::Error) {
    let mut slot = slot.lock().unwrap_or_else(PoisonError::into_inner);
    if slot.is_none() {
        *slot = Some(err);
    }
}

fn take_failure(slot: &Mutex<Option<anyhow::Error>>) -> Option<anyhow::Error> {
    slot.lock().unwrap_or_else(PoisonError::into_inner).take()
}

/// Per-shard reusable scratch: the sharded split of the monolithic
/// engine's `acc`/`touched`/`dsts` arrays, local-indexed so each worker
/// touches only its own cache lines.
struct ShardScratch {
    /// Reduction accumulator per owned vertex (local index), reset to the
    /// reduce identity after every writeback.
    acc: Vec<f64>,
    touched_flag: Vec<bool>,
    /// Local ids of vertices that received a message, insertion order.
    touched: Vec<u32>,
    /// Global destination stream (this shard's slice of the superstep
    /// trace).
    dsts: Vec<u32>,
    /// Boundary messages this superstep (foreign-source edges traversed).
    crossing: u64,
    /// Rows this shard opened (frontier rows pushed or owned rows swept).
    rows: u64,
    direction: Direction,
}

/// One shard's share of one superstep: direction decision, then the
/// push-scatter or pull-gather inner loop of the monolithic engine
/// restricted to this shard's slice. Runs on a worker thread; writes
/// only `scr`.
#[allow(clippy::too_many_arguments)]
fn process_shard(
    s: usize,
    shard: &Shard,
    scr: &mut ShardScratch,
    sg: &ShardedGraph,
    program: &GasProgram,
    compiled: CompiledApply,
    const_msg: f64,
    iter: u32,
    values: &[f64],
    cur: &Frontier,
    n: usize,
    active_policy: bool,
    policy: DirectionPolicy,
    crossover: Crossover,
    early_exit_ok: bool,
    sweep_unvisited_only: bool,
    unvisited: f64,
) {
    let is_unvisited = |x: f64| x == unvisited || (x.is_nan() && unvisited.is_nan());
    scr.dsts.clear();
    scr.touched.clear();
    scr.crossing = 0;
    // Per-shard direction decision (Graphitron-style): the frontier's
    // sub-row mass *into this shard* against this shard's edge count.
    // A frontier dense into one shard and sparse into another legally
    // splits push/pull within one superstep — values are unaffected
    // because both inner loops reduce in delivery order.
    let m_s = shard.push_dsts.len() as u64;
    scr.direction = match policy {
        DirectionPolicy::PushOnly => Direction::Push,
        DirectionPolicy::ForcePull => Direction::Pull,
        DirectionPolicy::Adaptive => {
            if !active_policy {
                Direction::Pull
            } else {
                let m_f: u64 =
                    cur.as_slice().iter().map(|&u| shard.push_row_len(u) as u64).sum();
                let alpha = crossover.alpha(early_exit_ok);
                if m_f.saturating_mul(alpha) >= m_s.max(1) {
                    Direction::Pull
                } else {
                    Direction::Push
                }
            }
        }
    };
    match scr.direction {
        Direction::Push => {
            scr.rows = if active_policy { cur.len() as u64 } else { n as u64 };
            let mut process_src = |u: VertexId| {
                let src_value = values[u as usize];
                let foreign = sg.owner[u as usize] as usize != s;
                for (v, w) in shard.push_row(u) {
                    let msg = eval_msg(
                        compiled,
                        &program.apply,
                        const_msg,
                        src_value,
                        || values[v as usize],
                        w,
                        iter,
                    );
                    let local = sg.local_id[v as usize] as usize;
                    if !scr.touched_flag[local] {
                        scr.touched_flag[local] = true;
                        scr.touched.push(local as u32);
                    }
                    let slot = &mut scr.acc[local];
                    *slot = reduce_combine(program.reduce, *slot, msg);
                    scr.dsts.push(v);
                    if foreign {
                        scr.crossing += 1;
                    }
                }
            };
            if active_policy {
                for &u in cur.as_slice() {
                    process_src(u);
                }
            } else {
                for u in 0..n as VertexId {
                    process_src(u);
                }
            }
        }
        Direction::Pull => {
            let mut swept = 0u64;
            for (local, &v) in shard.owned.iter().enumerate() {
                if sweep_unvisited_only && !is_unvisited(values[v as usize]) {
                    continue;
                }
                swept += 1;
                let dst_value = values[v as usize];
                for (u, w) in shard.pull_row(local as u32) {
                    scr.dsts.push(v);
                    if sg.owner[u as usize] as usize != s {
                        scr.crossing += 1;
                    }
                    if active_policy && !cur.contains(u) {
                        continue;
                    }
                    let src_value = values[u as usize];
                    let msg = eval_msg(
                        compiled,
                        &program.apply,
                        const_msg,
                        src_value,
                        || dst_value,
                        w,
                        iter,
                    );
                    if !scr.touched_flag[local] {
                        scr.touched_flag[local] = true;
                        scr.touched.push(local as u32);
                    }
                    let slot = &mut scr.acc[local];
                    *slot = reduce_combine(program.reduce, *slot, msg);
                    if early_exit_ok {
                        break;
                    }
                }
            }
            scr.rows = swept;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_generic_sharded(
    program: &GasProgram,
    facts: &crate::analysis::ProgramFacts,
    g: &EngineGraph<'_>,
    sg: &ShardedGraph,
    root: VertexId,
    policy: DirectionPolicy,
    workers: usize,
    faults: Option<&FaultPlan>,
    observer: &mut impl FnMut(&ShardedSuperstepTrace<'_>) -> Result<()>,
) -> Result<ShardedRun> {
    let csr = g.csr;
    let n = csr.num_vertices();
    let mut values = init_values(program, n, root);
    if n == 0 {
        return Ok(ShardedRun {
            result: GasResult {
                values,
                supersteps: 0,
                edges_traversed: 0,
                converged: true,
                pull_supersteps: 0,
            },
            crossing_msgs: 0,
        });
    }
    if matches!(program.init, InitPolicy::RootAndDefault { .. }) && (root as usize) >= n {
        anyhow::bail!("root {root} out of range for a {n}-vertex graph");
    }
    let unvisited = match &program.init {
        InitPolicy::RootAndDefault { default, .. } => default.lit(),
        _ => f64::NAN,
    };

    let active_policy = program.frontier == FrontierPolicy::Active;
    let mut cur = Frontier::new(n);
    let mut next = Frontier::new(n);
    if active_policy {
        match &program.init {
            InitPolicy::RootAndDefault { .. } => cur.push(root),
            _ => {
                for v in 0..n as VertexId {
                    cur.push(v);
                }
            }
        }
    }

    let depth_cap: f64 =
        program.depth_limit.as_ref().map(|s| s.lit()).unwrap_or(f64::INFINITY);
    let max_steps = program.max_supersteps(n);
    let compiled = CompiledApply::compile(&program.apply);
    let early_exit_ok = facts.pull_early_exit;
    let sweep_unvisited_only = active_policy && program.writeback == Writeback::IfUnvisited;
    let is_unvisited = |x: f64| x == unvisited || (x.is_nan() && unvisited.is_nan());
    // Merge discipline from the safety certificate (see module docs).
    let pinned = !matches!(facts.parallel_safety, ParallelSafety::BitExact);

    let k = sg.num_shards;
    let w = workers.min(k).max(1);
    let mut scratch: Vec<ShardScratch> = sg
        .shards
        .iter()
        .map(|sh| ShardScratch {
            acc: vec![reduce_identity(program.reduce); sh.num_owned()],
            touched_flag: vec![false; sh.num_owned()],
            touched: Vec::new(),
            dsts: Vec::new(),
            crossing: 0,
            rows: 0,
            direction: Direction::Push,
        })
        .collect();

    let mut shard_crossing = vec![0u64; k];
    let mut directions = vec![Direction::Push; k];
    let mut merge_order: Vec<usize> = (0..k).collect();

    let mut edges_traversed = 0u64;
    let mut crossing_msgs = 0u64;
    let mut supersteps = 0u32;
    let mut pull_supersteps = 0u32;
    let mut converged = false;

    for iter in 0..max_steps {
        let frontier_len = if active_policy { cur.len() } else { n };
        if frontier_len == 0 {
            converged = true;
            break;
        }
        // The frontier bitmap must exist before workers share `&cur`
        // (pull membership tests read it immutably).
        if active_policy && policy != DirectionPolicy::PushOnly {
            cur.ensure_bits();
        }
        let const_msg = program.apply.eval(&ApplyEnv {
            src_value: 0.0,
            dst_value: 0.0,
            edge_weight: 0.0,
            iter_count: iter as f64,
        });

        // Cost gate: a frontier this sparse finishes faster swept
        // serially than fanned out — scoped-spawn latency would dominate
        // the scatter. Serial and threaded supersteps fill the same
        // scratch, so the gate never changes values or traces.
        if w <= 1 || frontier_len < SHARD_DISPATCH_MIN_FRONTIER {
            let values_ref: &[f64] = &values;
            for (s, scr) in scratch.iter_mut().enumerate() {
                fence_shard(s, root, faults, || {
                    process_shard(
                        s,
                        &sg.shards[s],
                        scr,
                        sg,
                        program,
                        compiled,
                        const_msg,
                        iter,
                        values_ref,
                        &cur,
                        n,
                        active_policy,
                        policy,
                        g.crossover,
                        early_exit_ok,
                        sweep_unvisited_only,
                        unvisited,
                    )
                })?;
            }
        } else {
            // Static bucketing: shard s runs on worker s % w — placement
            // is deterministic, only completion timing varies. Worker 0's
            // bucket runs on the calling thread, so a pool of `w` workers
            // spawns only `w - 1` threads (the caller is one worker).
            let values_ref: &[f64] = &values;
            let cur_ref: &Frontier = &cur;
            let (tx, rx) = mpsc::channel::<usize>();
            let mut buckets: Vec<Vec<(usize, &mut ShardScratch)>> =
                (0..w).map(|_| Vec::new()).collect();
            for (s, scr) in scratch.iter_mut().enumerate() {
                buckets[s % w].push((s, scr));
            }
            // Panic-isolation fence (ISSUE 10): a shard worker that dies
            // records its failure here and stops sending — the scope
            // still joins every thread, then the query fails typed
            // below instead of unwinding across the scope boundary.
            let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            let failure_ref = &failure;
            std::thread::scope(|scope| {
                let mut buckets = buckets.into_iter();
                let mine = buckets.next().unwrap_or_default();
                for bucket in buckets {
                    let tx = tx.clone();
                    scope.spawn(move || {
                        for (s, scr) in bucket {
                            let fenced = fence_shard(s, root, faults, || {
                                process_shard(
                                    s,
                                    &sg.shards[s],
                                    scr,
                                    sg,
                                    program,
                                    compiled,
                                    const_msg,
                                    iter,
                                    values_ref,
                                    cur_ref,
                                    n,
                                    active_policy,
                                    policy,
                                    g.crossover,
                                    early_exit_ok,
                                    sweep_unvisited_only,
                                    unvisited,
                                )
                            });
                            match fenced {
                                Ok(()) => {
                                    let _ = tx.send(s);
                                }
                                Err(err) => {
                                    record_failure(failure_ref, err);
                                    return;
                                }
                            }
                        }
                    });
                }
                for (s, scr) in mine {
                    let fenced = fence_shard(s, root, faults, || {
                        process_shard(
                            s,
                            &sg.shards[s],
                            scr,
                            sg,
                            program,
                            compiled,
                            const_msg,
                            iter,
                            values_ref,
                            cur_ref,
                            n,
                            active_policy,
                            policy,
                            g.crossover,
                            early_exit_ok,
                            sweep_unvisited_only,
                            unvisited,
                        )
                    });
                    match fenced {
                        Ok(()) => {
                            let _ = tx.send(s);
                        }
                        Err(err) => {
                            record_failure(failure_ref, err);
                            break;
                        }
                    }
                }
            });
            drop(tx);
            if let Some(err) = take_failure(&failure) {
                // The merge below must not run on partial scratch; the
                // completion-order drain would also come up short of k.
                return Err(err);
            }
            if !pinned {
                // BitExact: merge in completion order. All sends landed
                // before the scope closed, so this drains exactly k.
                merge_order.clear();
                merge_order.extend(rx.try_iter());
                debug_assert_eq!(merge_order.len(), k);
            }
        }

        let mut active_rows = 0u64;
        for (s, scr) in scratch.iter().enumerate() {
            edges_traversed += scr.dsts.len() as u64;
            shard_crossing[s] = scr.crossing;
            directions[s] = scr.direction;
            active_rows += scr.rows;
        }
        crossing_msgs += shard_crossing.iter().sum::<u64>();
        if directions.iter().any(|d| *d == Direction::Pull) {
            pull_supersteps += 1;
        }

        {
            let shard_dsts: Vec<&[u32]> = scratch.iter().map(|scr| scr.dsts.as_slice()).collect();
            observer(&ShardedSuperstepTrace {
                index: iter,
                shard_dsts: &shard_dsts,
                shard_crossing: &shard_crossing,
                directions: &directions,
                active_rows,
            })?;
        }

        // Boundary-exchange merge: commit each shard's reduced messages.
        // Writebacks are disjoint (destination ownership), so this is the
        // monolithic writeback re-ordered by shard — same values, same
        // `changed` total, same next frontier after seal().
        next.clear();
        let mut changed = 0usize;
        let zero_fill = program.writeback == Writeback::Overwrite
            && program.frontier == FrontierPolicy::All
            && program.reduce == ReduceOp::Sum;
        for &s in &merge_order {
            let shard = &sg.shards[s];
            let scr = &mut scratch[s];
            if zero_fill {
                for (local, &v) in shard.owned.iter().enumerate() {
                    if !scr.touched_flag[local] && values[v as usize] != 0.0 {
                        values[v as usize] = 0.0;
                        changed += 1;
                    }
                }
            }
            for &local in scr.touched.iter() {
                let v = shard.owned[local as usize];
                let reduced = scr.acc[local as usize];
                let old = values[v as usize];
                let new = match program.writeback {
                    Writeback::MinCombine => old.min(reduced),
                    Writeback::MaxCombine => old.max(reduced),
                    Writeback::IfUnvisited => {
                        if is_unvisited(old) {
                            reduced
                        } else {
                            old
                        }
                    }
                    Writeback::Overwrite => reduced,
                    Writeback::DampedSum(_) => {
                        unreachable!("damped programs run in run_pagerank_sharded")
                    }
                };
                if new != old {
                    values[v as usize] = new;
                    changed += 1;
                    if active_policy {
                        next.push(v);
                    }
                }
                scr.acc[local as usize] = reduce_identity(program.reduce);
                scr.touched_flag[local as usize] = false;
            }
        }
        supersteps = iter + 1;

        let done = match &program.convergence {
            Convergence::EmptyFrontier => {
                if active_policy {
                    next.is_empty()
                } else {
                    changed == 0
                }
            }
            Convergence::NoChange => changed == 0,
            Convergence::FixedIterations(c) => supersteps >= *c,
            Convergence::DeltaBelow(_) => unreachable!("PR handled separately"),
        } || supersteps as f64 >= depth_cap;
        if done {
            converged = true;
            break;
        }
        if active_policy {
            next.seal();
            std::mem::swap(&mut cur, &mut next);
        }
    }

    Ok(ShardedRun {
        result: GasResult { values, supersteps, edges_traversed, converged, pull_supersteps },
        crossing_msgs,
    })
}

/// Per-shard PageRank scratch: the owner's slice of the `next` vector.
struct PrShardScratch {
    next_local: Vec<f64>,
}

/// One shard's PageRank gather: every owned destination sums its CSC row
/// in delivery order — the identical float sequence the monolithic
/// engine performs in either direction.
fn pr_gather(shard: &Shard, scr: &mut PrShardScratch, contrib: &[f64], base: f64, damping: f64) {
    for local in 0..shard.num_owned() {
        let mut sum = 0f64;
        for (u, _) in shard.pull_row(local as u32) {
            sum += contrib[u as usize];
        }
        scr.next_local[local] = base + damping * sum;
    }
}

/// Sharded PageRank. Ranks are bit-identical to [`super::gas`] in either
/// direction because each destination's sum accumulates over its pull
/// slice in delivery order; the policy only decides which trace stream
/// the observer sees (and the push/pull accounting), exactly like the
/// monolithic engine. Dangling mass, base, and the L1 delta are computed
/// serially ascending-vertex on the merge thread — never as shard-major
/// partial sums, which would reassociate the float reduction.
#[allow(clippy::too_many_arguments)]
fn run_pagerank_sharded(
    program: &GasProgram,
    g: &EngineGraph<'_>,
    sg: &ShardedGraph,
    root: VertexId,
    policy: DirectionPolicy,
    workers: usize,
    faults: Option<&FaultPlan>,
    observer: &mut impl FnMut(&ShardedSuperstepTrace<'_>) -> Result<()>,
) -> Result<ShardedRun> {
    let damping = match &program.writeback {
        Writeback::DampedSum(d) => d.lit(),
        other => unreachable!("run_pagerank_sharded dispatched on a non-damped writeback {other:?}"),
    };
    let tol = match &program.convergence {
        Convergence::DeltaBelow(t) => t.lit(),
        _ => 1e-6,
    };
    let csr = g.csr;
    let n = csr.num_vertices();
    let nf = n.max(1) as f64;
    let mut rank = vec![1.0 / nf; n];
    let mut next = vec![0f64; n];
    let deg_storage;
    let out_deg: &[u32] = match g.out_deg {
        Some(d) => d,
        None => {
            deg_storage = csr.out_degrees();
            &deg_storage
        }
    };

    // The shards carry their own pull slices, so the sharded engine can
    // always gather; the policy only picks the reported direction and
    // trace streams (push streams the shard's scatter order, pull its
    // CSC ascending runs), fixed for the whole run like the monolithic
    // PageRank.
    let pull = policy != DirectionPolicy::PushOnly;
    let direction = if pull { Direction::Pull } else { Direction::Push };
    let k = sg.num_shards;
    let w = workers.min(k).max(1);
    let shard_dsts: Vec<&[u32]> = sg
        .shards
        .iter()
        .map(|sh| if pull { sh.pull_dst_stream.as_slice() } else { sh.push_dsts.as_slice() })
        .collect();
    let shard_crossing: Vec<u64> = sg.shards.iter().map(|sh| sh.crossing_in).collect();
    let directions = vec![direction; k];

    let mut contrib = vec![0f64; n];
    let mut scratch: Vec<PrShardScratch> = sg
        .shards
        .iter()
        .map(|sh| PrShardScratch { next_local: vec![0f64; sh.num_owned()] })
        .collect();

    let mut edges_traversed = 0u64;
    let mut crossing_msgs = 0u64;
    let mut supersteps = 0u32;
    let mut pull_supersteps = 0u32;
    let mut converged = false;

    for iter in 0..program.delta_bound() {
        edges_traversed += csr.num_edges() as u64;
        observer(&ShardedSuperstepTrace {
            index: iter,
            shard_dsts: &shard_dsts,
            shard_crossing: &shard_crossing,
            directions: &directions,
            active_rows: n as u64,
        })?;
        crossing_msgs += sg.total_crossing;

        let dangling: f64 = (0..n).filter(|&v| out_deg[v] == 0).map(|v| rank[v]).sum();
        let base = (1.0 - damping) / nf + damping * dangling / nf;
        for v in 0..n {
            contrib[v] = rank[v] / out_deg[v].max(1) as f64;
        }

        if w <= 1 {
            let contrib_ref: &[f64] = &contrib;
            for (s, scr) in scratch.iter_mut().enumerate() {
                fence_shard(s, root, faults, || {
                    pr_gather(&sg.shards[s], scr, contrib_ref, base, damping)
                })?;
            }
        } else {
            let contrib_ref: &[f64] = &contrib;
            let mut buckets: Vec<Vec<(usize, &mut PrShardScratch)>> =
                (0..w).map(|_| Vec::new()).collect();
            for (s, scr) in scratch.iter_mut().enumerate() {
                buckets[s % w].push((s, scr));
            }
            // Worker 0's bucket runs on the calling thread (see the
            // generic loop): `w` workers spawn only `w - 1` threads.
            // Same panic-isolation discipline as the generic loop: a
            // dead worker fails the query typed, never the process.
            let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
            let failure_ref = &failure;
            std::thread::scope(|scope| {
                let mut buckets = buckets.into_iter();
                let mine = buckets.next().unwrap_or_default();
                for bucket in buckets {
                    scope.spawn(move || {
                        for (s, scr) in bucket {
                            let fenced = fence_shard(s, root, faults, || {
                                pr_gather(&sg.shards[s], scr, contrib_ref, base, damping)
                            });
                            if let Err(err) = fenced {
                                record_failure(failure_ref, err);
                                return;
                            }
                        }
                    });
                }
                for (s, scr) in mine {
                    let fenced = fence_shard(s, root, faults, || {
                        pr_gather(&sg.shards[s], scr, contrib_ref, base, damping)
                    });
                    if let Err(err) = fenced {
                        record_failure(failure_ref, err);
                        break;
                    }
                }
            });
            if let Some(err) = take_failure(&failure) {
                return Err(err);
            }
        }

        // Merge: disjoint scatter of each shard's owned slice, then the
        // L1 delta serially ascending — the monolithic summation order.
        for (s, scr) in scratch.iter().enumerate() {
            for (local, &v) in sg.shards[s].owned.iter().enumerate() {
                next[v as usize] = scr.next_local[local];
            }
        }
        let mut delta = 0.0;
        for v in 0..n {
            delta += (next[v] - rank[v]).abs();
        }
        std::mem::swap(&mut rank, &mut next);
        supersteps = iter + 1;
        if pull {
            pull_supersteps += 1;
        }
        if delta < tol {
            converged = true;
            break;
        }
    }
    Ok(ShardedRun {
        result: GasResult { values: rank, supersteps, edges_traversed, converged, pull_supersteps },
        crossing_msgs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl::algorithms;
    use crate::graph::csr::Csr;
    use crate::graph::{edgelist::EdgeList, generate};
    use crate::prep::partition::{partition, PartitionStrategy};

    fn sharded_setup(el: &EdgeList, k: usize, strat: PartitionStrategy) -> (Csr, Csr, ShardedGraph) {
        let csr = Csr::from_edgelist(el);
        let csc = csr.transpose();
        let p = partition(el, k, strat).unwrap();
        let sg = ShardedGraph::build(&csr, &csc, &p);
        (csr, csc, sg)
    }

    fn assert_bit_identical(a: &GasResult, b: &GasResult, ctx: &str) {
        assert_eq!(a.supersteps, b.supersteps, "{ctx}: supersteps");
        assert_eq!(a.converged, b.converged, "{ctx}: converged");
        assert_eq!(a.values.len(), b.values.len(), "{ctx}: len");
        for (v, (x, y)) in a.values.iter().zip(&b.values).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: vertex {v}: {x} vs {y}");
        }
    }

    #[test]
    fn sharded_bfs_matches_monolithic_across_shards_and_workers() {
        let el = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 7);
        let (csr, csc, _) = sharded_setup(&el, 1, PartitionStrategy::Range);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let mono =
            crate::engine::gas::run_with_policy(&algorithms::bfs(), &g, 0, DirectionPolicy::Adaptive, |_| {
                Ok(())
            })
            .unwrap();
        for k in [1usize, 2, 4, 7] {
            let (_, _, sg) = sharded_setup(&el, k, PartitionStrategy::DegreeBalanced);
            for workers in [1usize, 4] {
                let sh = run_sharded(
                    &algorithms::bfs(),
                    &g,
                    &sg,
                    0,
                    DirectionPolicy::Adaptive,
                    workers,
                    |_| Ok(()),
                )
                .unwrap();
                assert_bit_identical(&sh.result, &mono, &format!("bfs k={k} w={workers}"));
            }
        }
    }

    #[test]
    fn sharded_pagerank_matches_monolithic_bitwise() {
        let el = generate::rmat(8, 4_000, 0.57, 0.19, 0.19, 13);
        let (csr, csc, sg) = sharded_setup(&el, 4, PartitionStrategy::Hash);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let program = algorithms::pagerank()
            .instantiate(&ParamSet::new().bind("tolerance", 1e-4))
            .unwrap();
        let mono = crate::engine::gas::run_with_policy(
            &program,
            &g,
            0,
            DirectionPolicy::Adaptive,
            |_| Ok(()),
        )
        .unwrap();
        for workers in [1usize, 3] {
            let sh =
                run_sharded(&program, &g, &sg, 0, DirectionPolicy::Adaptive, workers, |_| Ok(()))
                    .unwrap();
            assert_bit_identical(&sh.result, &mono, &format!("pagerank w={workers}"));
            assert_eq!(
                sh.crossing_msgs,
                sg.total_crossing * sh.result.supersteps as u64,
                "dense sweeps exchange the full cut every superstep"
            );
        }
    }

    #[test]
    fn sharded_sssp_order_sensitive_sum_still_bit_identical() {
        // widest_path (Max) and sssp (Min) are BitExact; spmv (Sum over
        // All frontier) is the OrderSensitive case that exercises the
        // pinned merge path.
        let el = generate::rmat(8, 3_500, 0.5, 0.2, 0.2, 29);
        let (csr, csc, sg) = sharded_setup(&el, 4, PartitionStrategy::BfsGrow);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        for program in [algorithms::sssp(), algorithms::spmv(), algorithms::widest_path()] {
            let mono = crate::engine::gas::run_with_policy(
                &program,
                &g,
                2,
                DirectionPolicy::Adaptive,
                |_| Ok(()),
            )
            .unwrap();
            for workers in [1usize, 4] {
                let sh = run_sharded(&program, &g, &sg, 2, DirectionPolicy::Adaptive, workers, |_| {
                    Ok(())
                })
                .unwrap();
                assert_bit_identical(&sh.result, &mono, &format!("{} w={workers}", program.name));
            }
        }
    }

    #[test]
    fn sharded_trace_streams_partition_the_monolithic_work() {
        // Σ per-shard dsts per superstep == monolithic edges for push-only
        // (where both engines traverse exactly the frontier's out-edges).
        let el = generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 3);
        let (csr, csc, sg) = sharded_setup(&el, 3, PartitionStrategy::Range);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let mut mono_edges = Vec::new();
        let mono = crate::engine::gas::run_with_policy(
            &algorithms::bfs(),
            &g,
            0,
            DirectionPolicy::PushOnly,
            |t| {
                mono_edges.push(t.dsts.len());
                Ok(())
            },
        )
        .unwrap();
        let mut shard_edges = Vec::new();
        let mut crossings = 0u64;
        let sh = run_sharded(&algorithms::bfs(), &g, &sg, 0, DirectionPolicy::PushOnly, 2, |t| {
            shard_edges.push(t.shard_dsts.iter().map(|d| d.len()).sum::<usize>());
            crossings += t.shard_crossing.iter().sum::<u64>();
            Ok(())
        })
        .unwrap();
        assert_bit_identical(&sh.result, &mono, "push-only trace");
        assert_eq!(shard_edges, mono_edges);
        assert_eq!(crossings, sh.crossing_msgs);
        assert_eq!(sh.result.edges_traversed, mono.edges_traversed);
    }

    #[test]
    fn sharded_handles_empty_and_tiny_graphs() {
        // n == 0: converged fixpoint, no shards do anything
        let el = EdgeList { num_vertices: 0, edges: Vec::new() };
        let (csr, csc, sg) = sharded_setup(&el, 4, PartitionStrategy::Range);
        let g = EngineGraph::with_csc(&csr, &csc, None);
        // root-out-of-range applies only to n > 0; n == 0 short-circuits
        let sh =
            run_sharded(&algorithms::bfs(), &g, &sg, 0, DirectionPolicy::Adaptive, 4, |_| Ok(()))
                .unwrap();
        assert!(sh.result.converged);
        assert_eq!(sh.result.supersteps, 0);
        // single vertex per shard (n == k)
        let el = generate::chain(4);
        let (csr, csc, sg) = sharded_setup(&el, 4, PartitionStrategy::Range);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let mono =
            crate::engine::gas::run_with_policy(&algorithms::bfs(), &g, 0, DirectionPolicy::Adaptive, |_| {
                Ok(())
            })
            .unwrap();
        let sh = run_sharded(&algorithms::bfs(), &g, &sg, 0, DirectionPolicy::Adaptive, 4, |_| {
            Ok(())
        })
        .unwrap();
        assert_bit_identical(&sh.result, &mono, "n == k");
    }

    #[test]
    fn injected_shard_faults_fail_typed_and_leave_clean_runs_bit_identical() {
        let el = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 7);
        let (csr, csc, sg) = sharded_setup(&el, 4, PartitionStrategy::DegreeBalanced);
        let out_deg = csr.out_degrees();
        let g = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let bfs = algorithms::bfs();

        // An injected panic at shard 1 of root 0 comes back as a typed
        // WorkerPanic error — never an unwind across the engine.
        let plan = FaultPlan::parse(&format!("panic@shard#{}", shard_token(0, 1))).unwrap();
        let err = run_sharded_with_faults(
            &bfs, &g, &sg, 0, DirectionPolicy::Adaptive, 4, Some(&plan), |_| Ok(()),
        )
        .unwrap_err();
        let wp = err.downcast_ref::<WorkerPanic>().expect("typed WorkerPanic");
        assert_eq!(wp.shard, 1);
        assert!(wp.message.contains("injected fault: panic@shard"), "{}", wp.message);
        assert_eq!(plan.injected_total(), 1);

        // An injected error fault stays typed too (threaded PageRank path).
        let pr = algorithms::pagerank().instantiate(&ParamSet::new()).unwrap();
        let plan = FaultPlan::parse(&format!("exec_fail@shard#{}", shard_token(0, 2))).unwrap();
        let err = run_sharded_with_faults(
            &pr, &g, &sg, 0, DirectionPolicy::Adaptive, 3, Some(&plan), |_| Ok(()),
        )
        .unwrap_err();
        let inj = err.downcast_ref::<InjectedFault>().expect("typed InjectedFault");
        assert!(inj.transient());

        // A plan keyed to a different root never fires: the run completes
        // bit-identical to a fault-free run.
        let clean = run_sharded(&bfs, &g, &sg, 0, DirectionPolicy::Adaptive, 4, |_| Ok(())).unwrap();
        let miss = FaultPlan::parse(&format!("panic@shard#{}", shard_token(7, 1))).unwrap();
        let sh = run_sharded_with_faults(
            &bfs, &g, &sg, 0, DirectionPolicy::Adaptive, 4, Some(&miss), |_| Ok(()),
        )
        .unwrap();
        assert_eq!(miss.injected_total(), 0);
        assert_bit_identical(&sh.result, &clean.result, "non-matching plan");
    }
}
