//! Bench for Table V: regenerates the full table (both graphs, all three
//! translators, BFS) from the live system and times each stage of the flow
//! per translator.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::translator::{Translator, TranslatorKind};

fn main() {
    section("Table V regeneration (simulation timing; XLA path benched separately)");
    let (table, rows) = jgraph::report::table5(false, false).expect("table5");
    println!("{table}");

    // shape checks mirrored from the paper
    let j_small = rows.iter().find(|r| r.translator == "FAgraph" && r.graph.contains("email")).unwrap();
    let v_small = rows.iter().find(|r| r.translator == "Vivado HLS" && r.graph.contains("email")).unwrap();
    let s_small = rows.iter().find(|r| r.translator == "Spatial" && r.graph.contains("email")).unwrap();
    report_metric("TP ratio FAgraph/Vivado (paper ~1.6x)", j_small.mteps / v_small.mteps, "x");
    report_metric("TP ratio FAgraph/Spatial (paper ~16x)", j_small.mteps / s_small.mteps, "x");
    report_metric("lines ratio Spatial/FAgraph (paper ~3.7x)", s_small.code_lines as f64 / j_small.code_lines as f64, "x");
    report_metric("RT ratio Vivado/FAgraph (paper ~2.4x)", v_small.rt_seconds / j_small.rt_seconds, "x");

    section("per-stage timing (email-Eu-core, BFS)");
    let graph = generate::email_eu_core_like(42);
    let program = algorithms::bfs();
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    for kind in TranslatorKind::all() {
        bench(&format!("translate [{}]", kind.label()), 3, 20, || {
            Translator::of_kind(kind).translate(&program).unwrap()
        });
        let compiled = session.compile_with(Translator::of_kind(kind), &program).unwrap();
        bench(&format!("load (prep+deploy) [{}]", kind.label()), 1, 5, || {
            compiled.load(&graph, PrepOptions::named("email")).unwrap()
        });
        let mut bound = compiled.load(&graph, PrepOptions::named("email")).unwrap();
        bench(&format!("simulate+oracle query [{}]", kind.label()), 1, 5, || {
            bound.run(&RunOptions::default()).unwrap()
        });
    }
}
