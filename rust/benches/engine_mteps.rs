//! Engine MTEPS: push-only vs direction-optimizing execution of the
//! software GAS engine, both paths in the same binary over the same
//! graph. This is the bench behind the PR 5 tentpole claim (≥ 2× BFS
//! MTEPS on a 2^17-vertex rmat) and the `BENCH_engine.json` perf-trajectory
//! artifact CI tracks across PRs.
//!
//! Modes:
//! * default — 2^17-vertex rmat (~2M edges); **asserts** the ≥ 2× BFS
//!   speedup and refreshes `BENCH_engine.json`;
//! * `--quick` — small graph, few iterations, no threshold: the CI smoke
//!   that keeps the bench compiling and the JSON schema stable.
//!
//! MTEPS here uses the push path's traversed-edge count as the numerator
//! for **both** paths: the adaptive engine does *different* (less) work
//! per query, so a fair throughput comparison fixes the algorithmic work
//! and lets only wall time vary — speedup equals the wall-time ratio.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::dsl::params::ParamSet;
use jgraph::engine::gas::{self, DirectionPolicy, EngineGraph};
use jgraph::graph::csr::Csr;
use jgraph::graph::generate;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (scale, edges, warmup, iters) =
        if quick { (12u32, 100_000usize, 1, 3) } else { (17u32, 2_097_152usize, 1, 10) };
    let mode = if quick { "quick" } else { "full" };

    section(&format!("engine MTEPS, rmat scale {scale} ({edges} edges, mode {mode})"));
    let el = generate::rmat(scale, edges, 0.57, 0.19, 0.19, 7);
    let csr = Csr::from_edgelist(&el);
    let csc = csr.transpose();
    let out_deg = csr.out_degrees();
    let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
    // root at the highest-degree vertex: guaranteed inside the rmat core,
    // so the traversal covers the giant component
    let root = (0..csr.num_vertices() as u32).max_by_key(|&v| csr.degree(v)).unwrap_or(0);

    // --- BFS: the headline number
    let program = algorithms::bfs();
    let push_ref = gas::run(&program, &csr, root, |_| {}).unwrap();
    let adaptive_ref =
        gas::run_with_policy(&program, &view, root, DirectionPolicy::Adaptive, |_| Ok(()))
            .unwrap();
    // exactness pin (the property test does this over 100 random graphs;
    // here it guards the exact graph being measured)
    assert_eq!(push_ref.supersteps, adaptive_ref.supersteps, "superstep drift");
    assert!(
        push_ref
            .values
            .iter()
            .zip(&adaptive_ref.values)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "adaptive values drifted from the push reference"
    );
    println!(
        "BFS from root {root}: {} supersteps ({} pull), {} edges traversed (push) / {} (adaptive)",
        adaptive_ref.supersteps,
        adaptive_ref.pull_supersteps,
        push_ref.edges_traversed,
        adaptive_ref.edges_traversed,
    );

    let d_push = bench("BFS push-only", warmup, iters, || {
        gas::run(&program, &csr, root, |_| {}).unwrap().supersteps
    });
    let d_adaptive = bench("BFS adaptive (push/pull)", warmup, iters, || {
        gas::run_with_policy(&program, &view, root, DirectionPolicy::Adaptive, |_| Ok(()))
            .unwrap()
            .supersteps
    });
    let work = push_ref.edges_traversed as f64;
    let bfs_push_mteps = work / d_push.as_secs_f64() / 1e6;
    let bfs_adaptive_mteps = work / d_adaptive.as_secs_f64() / 1e6;
    let bfs_speedup = d_push.as_secs_f64() / d_adaptive.as_secs_f64();
    report_metric("BFS engine MTEPS (push-only)", bfs_push_mteps, "MTEPS");
    report_metric("BFS engine MTEPS (adaptive)", bfs_adaptive_mteps, "MTEPS");
    report_metric("BFS adaptive speedup", bfs_speedup, "x");

    // --- PageRank: every superstep dense, so the whole run pulls; the
    //     win here is the CSC gather + double-buffered scratch
    section("PageRank engine edge rate (push scatter vs pull gather)");
    let pr = algorithms::pagerank()
        .instantiate(&ParamSet::new().bind("tolerance", 1e-4))
        .unwrap();
    let pr_iters = iters.clamp(2, 5);
    let pr_ref = gas::run(&pr, &csr, root, |_| {}).unwrap();
    // hand the pull run the cached CSC-order trace stream, exactly as the
    // query layer does (PreparedGraph::pull_stream) — the push side
    // streams the pre-cached csr.targets, so timing a per-run rebuild
    // here would bias the comparison
    let pull_stream = csc.row_run_stream();
    let pr_view = view.with_pull_stream(&pull_stream);
    let d_pr_push = bench("PageRank push-only", 1, pr_iters, || {
        gas::run(&pr, &csr, root, |_| {}).unwrap().supersteps
    });
    let d_pr_pull = bench("PageRank pull (adaptive)", 1, pr_iters, || {
        gas::run_with_policy(&pr, &pr_view, root, DirectionPolicy::Adaptive, |_| Ok(()))
            .unwrap()
            .supersteps
    });
    let pr_work = pr_ref.edges_traversed as f64;
    let pr_push_meps = pr_work / d_pr_push.as_secs_f64() / 1e6;
    let pr_pull_meps = pr_work / d_pr_pull.as_secs_f64() / 1e6;
    let pr_speedup = d_pr_push.as_secs_f64() / d_pr_pull.as_secs_f64();
    report_metric("PR engine Medges/s (push-only)", pr_push_meps, "Medges/s");
    report_metric("PR engine Medges/s (pull)", pr_pull_meps, "Medges/s");
    report_metric("PR pull speedup", pr_speedup, "x");

    // --- perf-trajectory artifact (tracked across PRs by CI)
    let json = format!(
        "{{\n  \"bench\": \"engine_mteps\",\n  \"mode\": \"{mode}\",\n  \
         \"graph\": {{ \"kind\": \"rmat\", \"scale\": {scale}, \"vertices\": {}, \"edges\": {} }},\n  \
         \"bfs\": {{\n    \"supersteps\": {},\n    \"pull_supersteps\": {},\n    \
         \"push_mteps\": {bfs_push_mteps:.1},\n    \"adaptive_mteps\": {bfs_adaptive_mteps:.1},\n    \
         \"speedup\": {bfs_speedup:.2}\n  }},\n  \
         \"pagerank\": {{\n    \"supersteps\": {},\n    \"push_medges_per_s\": {pr_push_meps:.1},\n    \
         \"pull_medges_per_s\": {pr_pull_meps:.1},\n    \"speedup\": {pr_speedup:.2}\n  }}\n}}\n",
        csr.num_vertices(),
        csr.num_edges(),
        adaptive_ref.supersteps,
        adaptive_ref.pull_supersteps,
        pr_ref.supersteps,
    );
    std::fs::write("BENCH_engine.json", &json).expect("writing BENCH_engine.json");
    println!("\nwrote BENCH_engine.json:\n{json}");

    // quick mode is the CI smoke: no threshold, shared runners are noisy
    if !quick {
        assert!(
            bfs_speedup >= 2.0,
            "adaptive BFS must be >= 2x push-only on the 2^17 rmat (got {bfs_speedup:.2}x)"
        );
    }
}
