//! Bench for Figure 5: regenerates the development-cost breakdown and
//! reports the per-period ratios the figure's bars encode.

#[path = "harness.rs"]
mod harness;
use harness::*;

fn main() {
    section("Figure 5 regeneration (development-cost periods)");
    let (fig, rows) = jgraph::report::fig5_devcost().expect("fig5");
    println!("{fig}");

    let total = |tool: &str| rows.iter().find(|r| r.tool == tool).unwrap().total();
    report_metric("total cost Vivado/FAgraph", total("Vivado HLS") / total("FAgraph"), "x");
    report_metric("total cost Spatial/FAgraph", total("Spatial") / total("FAgraph"), "x");
    let fa = rows.iter().find(|r| r.tool == "FAgraph").unwrap();
    report_metric("FAgraph compile share of total", fa.compilation / fa.total(), "frac");

    section("figure generation timing");
    bench("fig5_devcost end-to-end", 1, 5, || jgraph::report::fig5_devcost().unwrap());
}
