//! Shard-worker scaling: the PR 7 tentpole perf claim. The sharded
//! engine executes k per-partition shards across a pool of worker
//! threads with a deterministic boundary merge; because destination
//! ownership makes every worker write a private accumulator slice, the
//! threaded sweep is bit-identical to the monolithic interpreter — so
//! any wall-time win is free. This bench measures that win on a
//! pull-heavy PageRank sweep and refreshes `BENCH_shard.json`, the
//! perf-trajectory artifact CI tracks across PRs.
//!
//! Modes:
//! * default — 2^15-vertex rmat (~1M edges), DegreeBalanced 4-way
//!   partition; **asserts** >= 1.5x query-exec speedup at 4 shard
//!   workers over 1;
//! * `--quick` — small graph, few iterations, no threshold: the CI
//!   smoke that keeps the bench compiling and the JSON schema stable.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::dsl::params::ParamSet;
use jgraph::engine::gas::{self, DirectionPolicy, EngineGraph};
use jgraph::engine::run_sharded;
use jgraph::graph::csr::Csr;
use jgraph::graph::generate;
use jgraph::prep::partition::{partition, PartitionStrategy};
use jgraph::prep::shard::ShardedGraph;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (scale, edges, tol, warmup, iters) = if quick {
        (11u32, 60_000usize, 1e-3, 1, 2)
    } else {
        (15u32, 1_048_576usize, 1e-4, 1, 5)
    };
    let mode = if quick { "quick" } else { "full" };
    let parts = 4usize;

    section(&format!(
        "shard-worker scaling, rmat scale {scale} ({edges} edges, {parts} shards, mode {mode})"
    ));
    let el = generate::rmat(scale, edges, 0.57, 0.19, 0.19, 7);
    let csr = Csr::from_edgelist(&el);
    let csc = csr.transpose();
    let out_deg = csr.out_degrees();
    let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
    let root = (0..csr.num_vertices() as u32).max_by_key(|&v| csr.degree(v)).unwrap_or(0);

    let p = partition(&el, parts, PartitionStrategy::DegreeBalanced).unwrap();
    let sg = ShardedGraph::build(&csr, &csc, &p);
    println!(
        "partition: {} cut edges ({:.1}% of {}), edge imbalance {:.3}",
        p.cut_edges,
        100.0 * p.cut_fraction(csr.num_edges()),
        csr.num_edges(),
        p.edge_imbalance(),
    );

    // pull-heavy sweep: PageRank runs every superstep dense, so the
    // sharded engine gathers over every shard's CSC slice each iteration
    let pr = algorithms::pagerank().instantiate(&ParamSet::new().bind("tolerance", tol)).unwrap();

    // exactness pin on the exact graph being measured (the property test
    // covers random graphs; this guards the bench configuration)
    let mono = gas::run(&pr, &csr, root, |_| {}).unwrap();
    let sharded_ref =
        run_sharded(&pr, &view, &sg, root, DirectionPolicy::PushOnly, 4, |_| Ok(())).unwrap();
    assert_eq!(mono.supersteps, sharded_ref.result.supersteps, "superstep drift");
    assert!(
        mono.values
            .iter()
            .zip(&sharded_ref.result.values)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "sharded values drifted from the monolithic reference"
    );
    println!(
        "PageRank: {} supersteps, {} crossing msgs/run",
        sharded_ref.result.supersteps, sharded_ref.crossing_msgs,
    );

    let time_workers = |w: usize, warmup: usize, iters: usize| {
        bench(&format!("PageRank sharded, {w} worker(s)"), warmup, iters, || {
            run_sharded(&pr, &view, &sg, root, DirectionPolicy::Adaptive, w, |_| Ok(()))
                .unwrap()
                .result
                .supersteps
        })
    };
    let d1 = time_workers(1, warmup, iters);
    let d2 = time_workers(2, warmup, iters);
    let d4 = time_workers(4, warmup, iters);
    let speedup2 = d1.as_secs_f64() / d2.as_secs_f64();
    let speedup4 = d1.as_secs_f64() / d4.as_secs_f64();
    report_metric("shard scaling speedup (2 workers)", speedup2, "x");
    report_metric("shard scaling speedup (4 workers)", speedup4, "x");

    let json = format!(
        "{{\n  \"bench\": \"shard_scaling\",\n  \"mode\": \"{mode}\",\n  \
         \"graph\": {{ \"kind\": \"rmat\", \"scale\": {scale}, \"vertices\": {}, \"edges\": {} }},\n  \
         \"shards\": {parts},\n  \"cut_edges\": {},\n  \"crossing_msgs\": {},\n  \
         \"supersteps\": {},\n  \
         \"seconds_1_worker\": {:.6},\n  \"seconds_2_workers\": {:.6},\n  \
         \"seconds_4_workers\": {:.6},\n  \
         \"speedup_2_workers\": {speedup2:.2},\n  \"speedup_4_workers\": {speedup4:.2}\n}}\n",
        csr.num_vertices(),
        csr.num_edges(),
        p.cut_edges,
        sharded_ref.crossing_msgs,
        sharded_ref.result.supersteps,
        d1.as_secs_f64(),
        d2.as_secs_f64(),
        d4.as_secs_f64(),
    );
    std::fs::write("BENCH_shard.json", &json).expect("writing BENCH_shard.json");
    println!("\nwrote BENCH_shard.json:\n{json}");

    // quick mode is the CI smoke: no threshold, shared runners are noisy.
    // The full-mode gate also needs the cores to exist: on a box with
    // fewer than 4 workers the 4-worker pool physically cannot beat 1,
    // so the wall-clock claim is only checkable where it can hold.
    let cores = jgraph::sched::available_workers();
    if !quick && cores >= 4 {
        assert!(
            speedup4 >= 1.5,
            "4 shard workers must be >= 1.5x over 1 on the 2^15 rmat (got {speedup4:.2}x)"
        );
    } else if !quick {
        println!("skipping the 1.5x gate: only {cores} worker(s) available");
    }
}
