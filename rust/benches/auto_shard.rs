//! Auto-shard scaling: the PR 8 tentpole perf claim. An *un-partitioned*
//! binding now auto-shards into degree-balanced destination ranges and
//! fans each superstep across worker threads — same bit-exact sharded
//! engine as user partitionings, zero user configuration. This bench
//! pins the exactness on the measured graph, measures the wall-time win
//! of the auto layout at 1/2/4 workers, and refreshes
//! `BENCH_autoshard.json`, the perf-trajectory artifact CI tracks.
//!
//! Modes:
//! * default — 2^15-vertex rmat (~1M edges) PageRank, auto-sharded
//!   4-way; **asserts** >= 1.5x speedup at 4 workers over 1 when the
//!   machine has >= 4 workers;
//! * `--quick` — small graph, few iterations, no threshold: the CI
//!   smoke that keeps the bench compiling and the JSON schema stable.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::dsl::params::ParamSet;
use jgraph::engine::gas::{self, DirectionPolicy};
use jgraph::engine::run_sharded;
use jgraph::graph::generate;
use jgraph::prep::partition::destination_ranges;
use jgraph::prep::prepared::{PrepOptions, PreparedGraph};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (scale, edges, tol, warmup, iters) = if quick {
        (11u32, 60_000usize, 1e-3, 1, 2)
    } else {
        (15u32, 1_048_576usize, 1e-4, 1, 5)
    };
    let mode = if quick { "quick" } else { "full" };
    let shards = 4usize;

    section(&format!(
        "auto-shard scaling, rmat scale {scale} ({edges} edges, {shards} auto-shards, mode {mode})"
    ));
    let el = generate::rmat(scale, edges, 0.57, 0.19, 0.19, 7);
    // Un-partitioned prepare: the auto layout is the only sharding. The
    // count is pinned so the measurement is machine-independent; the
    // automatic path picks the same layout with k = worker budget.
    let prepared =
        PreparedGraph::prepare(&el, &PrepOptions::named("rmat").with_auto_shards(shards))
            .unwrap();
    assert!(prepared.partitioning.is_none(), "bench must exercise the un-partitioned path");
    let sg = prepared.auto_sharded().expect("pinned auto-shards must engage");
    assert_eq!(sg.num_shards, shards);
    let p = destination_ranges(&prepared.csr, prepared.csc(), shards);
    println!(
        "auto layout: {} cut edges ({:.1}% of {}), edge imbalance {:.3}",
        p.cut_edges,
        100.0 * p.cut_fraction(prepared.num_edges()),
        prepared.num_edges(),
        p.edge_imbalance(),
    );

    let view = prepared.engine_view();
    let root = (0..prepared.num_vertices() as u32)
        .max_by_key(|&v| prepared.csr.degree(v))
        .unwrap_or(0);

    // pull-heavy sweep: PageRank gathers over every shard's CSC slice
    // each superstep — the workload auto-sharding exists to speed up
    let pr = algorithms::pagerank().instantiate(&ParamSet::new().bind("tolerance", tol)).unwrap();

    // exactness pin on the exact graph being measured (the property test
    // covers random graphs; this guards the bench configuration)
    let mono = gas::run(&pr, &prepared.csr, root, |_| {}).unwrap();
    let auto_ref =
        run_sharded(&pr, &view, sg, root, DirectionPolicy::Adaptive, 4, |_| Ok(())).unwrap();
    assert_eq!(mono.supersteps, auto_ref.result.supersteps, "superstep drift");
    assert!(
        mono.values
            .iter()
            .zip(&auto_ref.result.values)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "auto-sharded values drifted from the monolithic reference"
    );
    println!("PageRank: {} supersteps", auto_ref.result.supersteps);

    let time_workers = |w: usize, warmup: usize, iters: usize| {
        bench(&format!("PageRank auto-sharded, {w} worker(s)"), warmup, iters, || {
            run_sharded(&pr, &view, sg, root, DirectionPolicy::Adaptive, w, |_| Ok(()))
                .unwrap()
                .result
                .supersteps
        })
    };
    let d1 = time_workers(1, warmup, iters);
    let d2 = time_workers(2, warmup, iters);
    let d4 = time_workers(4, warmup, iters);
    let speedup2 = d1.as_secs_f64() / d2.as_secs_f64();
    let speedup4 = d1.as_secs_f64() / d4.as_secs_f64();
    report_metric("auto-shard speedup (2 workers)", speedup2, "x");
    report_metric("auto-shard speedup (4 workers)", speedup4, "x");

    let json = format!(
        "{{\n  \"bench\": \"auto_shard\",\n  \"mode\": \"{mode}\",\n  \
         \"graph\": {{ \"kind\": \"rmat\", \"scale\": {scale}, \"vertices\": {}, \"edges\": {} }},\n  \
         \"auto_shards\": {shards},\n  \"cut_edges\": {},\n  \
         \"supersteps\": {},\n  \
         \"seconds_1_worker\": {:.6},\n  \"seconds_2_workers\": {:.6},\n  \
         \"seconds_4_workers\": {:.6},\n  \
         \"speedup_2_workers\": {speedup2:.2},\n  \"speedup_4_workers\": {speedup4:.2}\n}}\n",
        prepared.num_vertices(),
        prepared.num_edges(),
        p.cut_edges,
        auto_ref.result.supersteps,
        d1.as_secs_f64(),
        d2.as_secs_f64(),
        d4.as_secs_f64(),
    );
    std::fs::write("BENCH_autoshard.json", &json).expect("writing BENCH_autoshard.json");
    println!("\nwrote BENCH_autoshard.json:\n{json}");

    // quick mode is the CI smoke: no threshold. The full-mode gate also
    // needs the cores to exist — a box with fewer than 4 workers cannot
    // make a 4-worker pool beat 1.
    let cores = jgraph::sched::available_workers();
    if !quick && cores >= 4 {
        assert!(
            speedup4 >= 1.5,
            "4 auto-shard workers must be >= 1.5x over 1 on the 2^15 rmat (got {speedup4:.2}x)"
        );
    } else if !quick {
        println!("skipping the 1.5x gate: only {cores} worker(s) available");
    }
}
