//! Minimal benchmark harness (the offline build has no criterion):
//! warmup + N timed iterations, reporting min/median/mean like criterion's
//! terse output. Shared by every bench target via `#[path] mod harness`.

use std::time::{Duration, Instant};

/// Time `f` over `iters` iterations after `warmup` runs; prints a
/// criterion-style line and returns the median.
pub fn bench<R>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> Duration {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let min = samples[0];
    let median = samples[samples.len() / 2];
    let mean: Duration = samples.iter().sum::<Duration>() / samples.len() as u32;
    println!(
        "{name:<48} min {:>12} median {:>12} mean {:>12} ({iters} iters)",
        fmt(min),
        fmt(median),
        fmt(mean)
    );
    median
}

/// Record a derived metric (throughput, ratio) in the bench output.
#[allow(dead_code)] // not every bench target reports derived metrics
pub fn report_metric(name: &str, value: f64, unit: &str) {
    println!("{name:<48} {value:>12.3} {unit}");
}

fn fmt(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
