//! Ablation: the runtime scheduler's knobs (paper §V-C2) — pipelines × PEs
//! scaling of simulated throughput, the BRAM vertex cache effect, and the
//! auto-planner's chosen operating point.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::accel::device::DeviceModel;
use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::sched::{scheduler::auto_plan, ParallelismPlan};
use jgraph::translator::{resource::ResourceEstimate, Translator, TranslatorKind};

fn main() {
    let graph = generate::rmat(13, 200_000, 0.57, 0.19, 0.19, 6);
    let program = algorithms::bfs();
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });

    section("pipelines x PEs scaling (BFS, rmat-13, simulated MTEPS)");
    println!("  {:>9} | {:>4} | {:>10} | {:>12}", "pipelines", "pes", "MTEPS", "LUT used");
    for (pipes, pes) in [(1u32, 1u32), (2, 1), (4, 1), (8, 1), (16, 1), (8, 2), (16, 2), (32, 2)] {
        let translator = Translator::jgraph().with_plan(ParallelismPlan::new(pipes, pes));
        let compiled = session.compile_with(translator, &program).unwrap();
        let mut bound = compiled.load(&graph, PrepOptions::named("rmat13")).unwrap();
        let r = bound.run(&RunOptions::default()).unwrap();
        println!(
            "  {:>9} | {:>4} | {:>10.2} | {:>12}",
            pipes,
            pes,
            r.simulated_mteps,
            compiled.design().resources.lut
        );
    }

    section("BRAM vertex cache ablation (same plan, cache on/off)");
    // the vivado flow is the no-cache datapath at II=2; compare against a
    // jgraph flow at the same II by scaling lanes to normalize issue rate
    for kind in [TranslatorKind::JGraph, TranslatorKind::VivadoHls] {
        let compiled = session.compile_with(Translator::of_kind(kind), &program).unwrap();
        let mut bound = compiled.load(&graph, PrepOptions::named("rmat13")).unwrap();
        let r = bound.run(&RunOptions::default()).unwrap();
        println!(
            "  {:>10} | cache {:>5} | {:>8.2} MTEPS | vertex_random cycles {:>10}",
            kind.label(),
            compiled.design().pipeline.bram_vertex_cache,
            r.simulated_mteps,
            r.sim.cycles.vertex_random
        );
    }

    section("auto-planner operating point");
    let per_lane = ResourceEstimate {
        lut: 15_000,
        ff: 20_000,
        bram_kb: 400,
        uram: 16,
        dsp: 8,
    };
    let plan = auto_plan(&per_lane, &DeviceModel::u200(), 128, 8);
    report_metric("auto plan pipelines", plan.pipelines as f64, "");
    report_metric("auto plan PEs", plan.pes as f64, "");
    report_metric(
        "auto plan LUT utilization",
        per_lane.scaled(plan.total_lanes()).utilization(&DeviceModel::u200())[0],
        "frac",
    );

    section("scheduler admission cost");
    bench("admit 8x1 (fits)", 10, 100, || {
        jgraph::sched::scheduler::RuntimeScheduler::admit(
            ParallelismPlan::new(8, 1),
            &per_lane,
            &DeviceModel::u200(),
            100,
        )
        .unwrap()
    });
}
