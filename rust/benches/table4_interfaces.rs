//! Bench for Table IV: interface-count comparison + registry query costs.
//! Regenerates the table the paper prints and checks the headline (ours >
//! every comparator).

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::registry;

fn main() {
    section("Table IV: graph atomic operators (regeneration)");
    println!("{}", jgraph::report::table4());

    let ours = registry::interface_count();
    for row in registry::table4_comparators() {
        report_metric(
            &format!("interface ratio vs {}", row.system),
            ours as f64 / row.operator_count as f64,
            "x",
        );
    }

    section("registry query microbenchmarks");
    bench("interface_count", 100, 1000, registry::interface_count);
    bench("by_level(Function)", 100, 1000, || {
        registry::by_level(jgraph::dsl::ops::Level::Function).len()
    });
    bench("find(\"Receive\")", 100, 1000, || registry::find("Receive").is_some());
}
