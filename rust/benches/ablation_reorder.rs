//! Ablation: Reorder strategies (paper §IV-C4). Measures the simulated
//! MTEPS and row-start stall cycles of BFS/SSSP under each strategy, on a
//! shuffled grid (locality-sensitive) and an R-MAT power-law graph.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::prep::reorder::{all_strategies, ReorderStrategy};

fn shuffled_grid() -> jgraph::graph::edgelist::EdgeList {
    let grid = generate::grid2d(64, 64, 7);
    let mut rng = jgraph::graph::SplitMix64::new(1);
    let mut perm: Vec<u32> = (0..grid.num_vertices as u32).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        perm.swap(i, j);
    }
    grid.permute(&perm)
}

fn main() {
    let graphs = vec![
        ("shuffled-grid-64", shuffled_grid()),
        ("rmat-12", generate::rmat(12, 80_000, 0.57, 0.19, 0.19, 4)),
    ];
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    for (gname, graph) in &graphs {
        for program in [algorithms::bfs(), algorithms::sssp()] {
            section(&format!("{} on {gname}", program.name));
            // compile once per program; one load per reorder strategy
            let compiled = session.compile(&program).unwrap();
            for &strategy in all_strategies() {
                let mut prep = PrepOptions::named(gname.to_string());
                prep.reorder =
                    if strategy == ReorderStrategy::None { None } else { Some(strategy) };
                let mut bound = compiled.load(graph, prep).unwrap();
                let r = bound.run(&RunOptions::default()).unwrap();
                println!(
                    "  {:>14} | {:>8.2} MTEPS | row-start {:>9} | conflict {:>9} | prep {:>6.1} ms",
                    format!("{strategy:?}"),
                    r.simulated_mteps,
                    r.sim.cycles.row_start,
                    r.sim.cycles.conflict,
                    r.prep_seconds * 1e3
                );
            }
        }
    }

    section("reorder preprocessing cost");
    let g = generate::rmat(14, 400_000, 0.57, 0.19, 0.19, 5);
    for &s in all_strategies() {
        bench(&format!("permutation [{s:?}] rmat-14"), 1, 5, || {
            jgraph::prep::reorder::permutation(&g, s)
        });
    }
}
