//! Serve-path tail latency: an in-process `jgraph serve` daemon under
//! open-loop load, measured from the client side of a real TCP socket.
//! Client timestamps give *exact* per-query latencies (no histogram
//! bucketing), so the p50/p95/p99 written to `BENCH_serve.json` are the
//! ground truth the daemon's own HDR-style histograms approximate —
//! the bench prints both so the approximation error is visible.
//!
//! Phases:
//! * closed loop (1 in-flight) — pure round-trip floor, batches of 1;
//! * windowed load (8 in-flight, pipelined) — the arrival batcher gets
//!   company, so occupancy rises and per-query service cost amortizes.
//!
//! Modes:
//! * default — 2^13-vertex graphs, 256 queries per phase;
//! * `--quick` — tiny graphs, 32 queries: the CI smoke that keeps the
//!   bench compiling and the JSON schema stable. No latency thresholds
//!   in either mode — shared runners make wall-clock gates flake; the
//!   artifact records the trajectory instead.

#[path = "harness.rs"]
mod harness;
use harness::*;

use std::sync::Arc;
use std::time::{Duration, Instant};

use jgraph::engine::{Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::serve::{QueryRequest, ServeClient, ServeConfig, ServeRegistry, Server};

fn query(graph: &str, algo: &str, root: u32) -> QueryRequest {
    QueryRequest {
        graph: graph.into(),
        algo: algo.into(),
        root,
        params: Vec::new(),
        direction: None,
        tenant: "bench".into(),
        max_supersteps: None,
        deadline_us: None,
    }
}

/// Exact percentile over client-side samples (nearest-rank).
fn percentile_us(sorted: &[Duration], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank - 1].as_secs_f64() * 1e6
}

struct Load {
    p50_us: f64,
    p95_us: f64,
    p99_us: f64,
    qps: f64,
}

/// Drive `n` queries with up to `window` pipelined in flight, returning
/// exact client-side latency percentiles and achieved throughput.
fn drive(client: &mut ServeClient, n: usize, window: usize, mix: &[QueryRequest]) -> Load {
    let t0 = Instant::now();
    let mut sent_at = std::collections::VecDeque::with_capacity(window);
    let mut latencies = Vec::with_capacity(n);
    let mut sent = 0usize;
    let mut received = 0usize;
    while received < n {
        while sent < n && sent_at.len() < window {
            client.send_query(&mix[sent % mix.len()]).expect("send");
            sent_at.push_back(Instant::now());
            sent += 1;
        }
        let resp = client.recv().expect("recv");
        let issued: Instant = sent_at.pop_front().expect("response without a send");
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "bench query failed: {}",
            resp.render()
        );
        latencies.push(issued.elapsed());
        received += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    latencies.sort();
    Load {
        p50_us: percentile_us(&latencies, 50.0),
        p95_us: percentile_us(&latencies, 95.0),
        p99_us: percentile_us(&latencies, 99.0),
        qps: n as f64 / elapsed,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (vertices, edges, queries) = if quick {
        (512usize, 4_096usize, 32usize)
    } else {
        (8_192usize, 65_536usize, 256usize)
    };
    let mode = if quick { "quick" } else { "full" };

    section(&format!(
        "serve tail latency ({vertices}v/{edges}e graphs, {queries} queries/phase, mode {mode})"
    ));
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let registry = Arc::new(ServeRegistry::new(session, 4));
    registry.register_edges("er", generate::erdos_renyi(vertices, edges, 11));
    registry.register_edges("grid", generate::grid2d(64, 64, 11));
    let config = ServeConfig { batch_window: Duration::from_millis(2), ..Default::default() };
    let server = Server::start(config, registry).expect("server start");
    let mut client = ServeClient::connect(server.local_addr()).expect("connect");

    // mixed binding traffic: two graphs x two algorithms
    let mix: Vec<QueryRequest> = (0..16u32)
        .map(|i| {
            let graph = if i % 2 == 0 { "er" } else { "grid" };
            let algo = if i % 4 < 2 { "bfs" } else { "pagerank" };
            query(graph, algo, i * 37 % vertices as u32)
        })
        .collect();

    // warm the registry (graph prep + pipeline compile off the clock)
    for q in &mix {
        let resp = client.query(q).expect("warmup");
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{}", resp.render());
    }

    // protocol floor: one line out, one line back, no execution
    let ping = bench("ping round-trip", 8, 64, || client.ping().expect("ping"));
    let ping_us = ping.as_secs_f64() * 1e6;

    let closed = drive(&mut client, queries, 1, &mix);
    report_metric("closed-loop p50", closed.p50_us, "us");
    report_metric("closed-loop p99", closed.p99_us, "us");
    report_metric("closed-loop throughput", closed.qps, "queries/s");

    let windowed = drive(&mut client, queries, 8, &mix);
    report_metric("windowed(8) p50", windowed.p50_us, "us");
    report_metric("windowed(8) p99", windowed.p99_us, "us");
    report_metric("windowed(8) throughput", windowed.qps, "queries/s");

    // the daemon's own accounting, for comparison with the exact
    // client-side numbers above (bucketed: <= 6.25% relative error)
    let stats = client.stats().expect("stats");
    let served = stats.get("served").and_then(|v| v.as_u64()).unwrap_or(0);
    let occupancy = stats.get("mean_batch_occupancy").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let server_p99 = stats
        .get("total")
        .and_then(|t| t.get("p99_us"))
        .and_then(|v| v.as_f64())
        .unwrap_or(f64::NAN);
    report_metric("server-side total p99 (bucketed)", server_p99, "us");
    report_metric("mean batch occupancy", occupancy, "queries/sweep");
    assert_eq!(served as usize, mix.len() + 2 * queries, "daemon lost queries");

    let json = format!(
        "{{\n  \"bench\": \"serve_latency\",\n  \"mode\": \"{mode}\",\n  \
         \"graphs\": {{ \"er_vertices\": {vertices}, \"er_edges\": {edges}, \"grid\": \"64x64\" }},\n  \
         \"queries_per_phase\": {queries},\n  \"batch_window_us\": 2000,\n  \
         \"ping_round_trip_us\": {ping_us:.1},\n  \
         \"closed_loop\": {{ \"p50_us\": {:.1}, \"p95_us\": {:.1}, \"p99_us\": {:.1}, \"qps\": {:.1} }},\n  \
         \"windowed_8\": {{ \"p50_us\": {:.1}, \"p95_us\": {:.1}, \"p99_us\": {:.1}, \"qps\": {:.1} }},\n  \
         \"mean_batch_occupancy\": {occupancy:.2}\n}}\n",
        closed.p50_us,
        closed.p95_us,
        closed.p99_us,
        closed.qps,
        windowed.p50_us,
        windowed.p95_us,
        windowed.p99_us,
        windowed.qps,
    );
    std::fs::write("BENCH_serve.json", &json).expect("writing BENCH_serve.json");
    println!("\nwrote BENCH_serve.json:\n{json}");

    client.shutdown().expect("shutdown ack");
    drop(client);
    server.join().expect("clean join");
}
