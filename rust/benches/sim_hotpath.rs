//! Microbenchmarks of the L3 hot path: the cycle simulator's per-edge
//! bank-conflict loop, the software GAS engine inner loop, and the XLA
//! superstep round-trip. This is the bench the §Perf pass iterates on
//! (EXPERIMENTS.md records before/after).

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::accel::bram::BankModel;
use jgraph::accel::device::DeviceModel;
use jgraph::accel::simulator::{AccelSimulator, EdgeBatch};
use jgraph::dsl::algorithms;
use jgraph::dsl::program::Direction;
use jgraph::engine::gas::{DirectionPolicy, EngineGraph};
use jgraph::engine::{gas, RunOptions, Session, SessionConfig};
use jgraph::graph::csr::Csr;
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::pipeline::schedule;
use jgraph::translator::TranslatorKind;

fn main() {
    let mut rng = jgraph::graph::SplitMix64::new(9);
    let dsts_1m: Vec<u32> = (0..1_000_000).map(|_| rng.next_below(100_000) as u32).collect();

    section("bank-conflict window loop (1M edges)");
    let mut bank = BankModel::new(16);
    let d = bench("window_cycles 1M edges, 8 lanes", 2, 20, || {
        let mut total = 0u64;
        for w in dsts_1m.chunks(8) {
            total += bank.window_cycles(w, 1) as u64;
        }
        total
    });
    report_metric(
        "conflict-loop throughput",
        1.0e9 / (d.as_nanos() as f64 / 1_000_000.0) / 1e6,
        "Medges/s",
    );

    section("full simulator superstep (1M edges)");
    let dev = DeviceModel::u200();
    let spec = schedule(TranslatorKind::JGraph, ParallelismPlan::default(), 20, dev.clock_hz);
    let d = bench("simulate 1M-edge superstep", 2, 20, || {
        let mut sim = AccelSimulator::new(DeviceModel::u200(), spec);
        sim.superstep(&EdgeBatch {
            dsts: &dsts_1m,
            active_rows: 100_000,
            bytes_per_edge: 8,
            avg_edge_gap: 3_000.0,
            direction: Direction::Push,
        });
        sim.finish().cycles.total()
    });
    report_metric(
        "simulator throughput",
        1.0e9 / (d.as_nanos() as f64 / 1_000_000.0) / 1e6,
        "Medges/s",
    );

    section("software GAS engine (BFS, rmat-13 ~200k edges)");
    let g = generate::rmat(13, 200_000, 0.57, 0.19, 0.19, 3);
    let csr = Csr::from_edgelist(&g);
    let program = algorithms::bfs();
    let d = bench("gas::run BFS rmat-13 (push-only)", 1, 10, || {
        gas::run(&program, &csr, 0, |_| {}).unwrap().edges_traversed
    });
    let traversed = gas::run(&program, &csr, 0, |_| {}).unwrap().edges_traversed;
    report_metric(
        "software-oracle throughput (push)",
        traversed as f64 / d.as_secs_f64() / 1e6,
        "Medges/s",
    );
    // direction-optimizing path over the cached CSC (same values, same
    // supersteps; see benches/engine_mteps.rs for the full comparison)
    let csc = csr.transpose();
    let out_deg = csr.out_degrees();
    let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
    let d_adaptive = bench("gas::run_adaptive BFS rmat-13", 1, 10, || {
        gas::run_with_policy(&program, &view, 0, DirectionPolicy::Adaptive, |_| Ok(()))
            .unwrap()
            .edges_traversed
    });
    report_metric(
        "software-oracle throughput (adaptive)",
        traversed as f64 / d_adaptive.as_secs_f64() / 1e6,
        "Medges/s",
    );
    report_metric(
        "adaptive speedup (push/adaptive wall)",
        d.as_secs_f64() / d_adaptive.as_secs_f64(),
        "x",
    );

    section("CSR construction (rmat-14 ~500k edges)");
    let big = generate::rmat(14, 500_000, 0.57, 0.19, 0.19, 4);
    bench("Csr::from_edgelist rmat-14", 1, 10, || Csr::from_edgelist(&big));
    bench("to_padded_coo 1M slots", 1, 10, || Csr::from_edgelist(&big).to_padded_coo(1_048_576));

    section("compile_once_run_many (BFS, rmat-13, software path)");
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let program = algorithms::bfs();
    let qgraph = generate::rmat(13, 200_000, 0.57, 0.19, 0.19, 3);
    // cold: the full lifecycle per query (what the one-shot API pays)
    let d_cold = bench("cold query: compile + load + run", 1, 10, || {
        let compiled = session.compile(&program).unwrap();
        let mut bound = compiled.load(&qgraph, PrepOptions::named("rmat-13")).unwrap();
        bound.run(&RunOptions::from_root(0)).unwrap().edges_traversed
    });
    // warm: compile + load once, then run-many
    let compiled = session.compile(&program).unwrap();
    let mut bound = compiled.load(&qgraph, PrepOptions::named("rmat-13")).unwrap();
    let d_warm = bench("warm query: bound.run", 1, 10, || {
        bound.run(&RunOptions::from_root(0)).unwrap().edges_traversed
    });
    report_metric(
        "compile/load amortization (cold/warm)",
        d_cold.as_secs_f64() / d_warm.as_secs_f64(),
        "x",
    );
    // amortized per-query MTEPS across a 16-root sweep on one binding
    let roots: Vec<RunOptions> = (0..16)
        .map(|i| RunOptions::from_root(qgraph.edges[(i * 12_553) % qgraph.num_edges()].src))
        .collect();
    let t0 = std::time::Instant::now();
    let reports = bound.run_batch(&roots).unwrap();
    let sweep_seconds = t0.elapsed().as_secs_f64();
    let mean_mteps =
        reports.iter().map(|r| r.simulated_mteps).sum::<f64>() / reports.len() as f64;
    report_metric("amortized per-query MTEPS (16 roots)", mean_mteps, "MTEPS");
    report_metric(
        "per-query wall across 16-root sweep",
        sweep_seconds / reports.len() as f64 * 1e3,
        "ms",
    );

    section("XLA superstep round-trip (requires artifacts)");
    match jgraph::runtime::KernelRegistry::open_default() {
        Ok(reg) => {
            let small = generate::email_eu_core_like(42);
            let csr_s = Csr::from_edgelist(&small);
            let exe = reg.for_graph("bfs", csr_s.num_vertices(), csr_s.num_edges()).unwrap();
            let coo = csr_s.to_padded_coo(exe.meta.m);
            let n_pad = exe.meta.n;
            let mut levels = vec![-1i32; n_pad];
            levels[0] = 0;
            let mut frontier = vec![0i32; n_pad];
            frontier[0] = 1;
            let args = vec![
                jgraph::runtime::Buffer::I32(levels),
                jgraph::runtime::Buffer::I32(frontier),
                jgraph::runtime::Buffer::I32(coo.src),
                jgraph::runtime::Buffer::I32(coo.dst),
                jgraph::runtime::Buffer::I32(vec![coo.num_edges as i32]),
                jgraph::runtime::Buffer::I32(vec![0]),
            ];
            let d = bench("bfs superstep [small bucket, fresh literals]", 3, 30, || {
                exe.run(&args).unwrap()
            });
            report_metric(
                "XLA-path edge rate (fresh literals)",
                coo.num_edges as f64 / d.as_secs_f64() / 1e6,
                "Medges/s",
            );
            // §Perf: static COO operands prepared once, reused per superstep
            use jgraph::runtime::client::ArgRef;
            let (src_l, dst_l, ne_l) = (
                exe.prepare(2, &args[2]).unwrap(),
                exe.prepare(3, &args[3]).unwrap(),
                exe.prepare(4, &args[4]).unwrap(),
            );
            let d = bench("bfs superstep [small bucket, cached statics]", 3, 30, || {
                exe.run_args(&[
                    ArgRef::Buf(&args[0]),
                    ArgRef::Buf(&args[1]),
                    ArgRef::Lit(&src_l),
                    ArgRef::Lit(&dst_l),
                    ArgRef::Lit(&ne_l),
                    ArgRef::Buf(&args[5]),
                ])
                .unwrap()
            });
            report_metric(
                "XLA-path edge rate (cached statics)",
                coo.num_edges as f64 / d.as_secs_f64() / 1e6,
                "Medges/s",
            );
            // large bucket: the copy saving is ~12 MB/superstep
            let exe_l = reg.for_bucket("bfs", "large").unwrap();
            let big = generate::soc_slashdot_like(42);
            let csr_l = Csr::from_edgelist(&big);
            let coo_l = csr_l.to_padded_coo(exe_l.meta.m);
            let nl = exe_l.meta.n;
            let mut lv = vec![-1i32; nl];
            lv[0] = 0;
            let mut fr = vec![0i32; nl];
            fr[0] = 1;
            let args_l = vec![
                jgraph::runtime::Buffer::I32(lv),
                jgraph::runtime::Buffer::I32(fr),
                jgraph::runtime::Buffer::I32(coo_l.src),
                jgraph::runtime::Buffer::I32(coo_l.dst),
                jgraph::runtime::Buffer::I32(vec![coo_l.num_edges as i32]),
                jgraph::runtime::Buffer::I32(vec![0]),
            ];
            let d_fresh = bench("bfs superstep [large bucket, fresh literals]", 1, 10, || {
                exe_l.run(&args_l).unwrap()
            });
            let (src_l, dst_l, ne_l) = (
                exe_l.prepare(2, &args_l[2]).unwrap(),
                exe_l.prepare(3, &args_l[3]).unwrap(),
                exe_l.prepare(4, &args_l[4]).unwrap(),
            );
            let d_cached = bench("bfs superstep [large bucket, cached statics]", 1, 10, || {
                exe_l
                    .run_args(&[
                        ArgRef::Buf(&args_l[0]),
                        ArgRef::Buf(&args_l[1]),
                        ArgRef::Lit(&src_l),
                        ArgRef::Lit(&dst_l),
                        ArgRef::Lit(&ne_l),
                        ArgRef::Buf(&args_l[5]),
                    ])
                    .unwrap()
            });
            report_metric(
                "large-bucket superstep speedup (cached/fresh)",
                d_fresh.as_secs_f64() / d_cached.as_secs_f64(),
                "x",
            );
        }
        Err(e) => println!("skipped ({e:#})"),
    }
}
