//! Ablation: Partition strategies (paper §IV-C3). Reports cut fraction
//! and edge balance per strategy and part count, plus partitioning cost.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::graph::generate;
use jgraph::prep::partition::{partition, PartitionStrategy};

const STRATEGIES: [PartitionStrategy; 4] = [
    PartitionStrategy::Range,
    PartitionStrategy::Hash,
    PartitionStrategy::DegreeBalanced,
    PartitionStrategy::BfsGrow,
];

fn main() {
    let graphs = vec![
        ("rmat-13 (power-law)", generate::rmat(13, 160_000, 0.57, 0.19, 0.19, 2)),
        ("grid-90 (planar)", generate::grid2d(90, 90, 2)),
    ];
    for (gname, g) in &graphs {
        for k in [2usize, 4, 8] {
            section(&format!("{gname}, k = {k}"));
            for s in STRATEGIES {
                let p = partition(g, k, s).unwrap();
                println!(
                    "  {:>16} | cut {:>6.2}% | imbalance {:>5.2} | max part edges {:>8}",
                    format!("{s:?}"),
                    100.0 * p.cut_fraction(g.num_edges()),
                    p.edge_imbalance(),
                    p.part_edges.iter().max().unwrap()
                );
            }
        }
    }

    section("partitioning cost (rmat-14, k=8)");
    let g = generate::rmat(14, 500_000, 0.57, 0.19, 0.19, 3);
    for s in STRATEGIES {
        bench(&format!("partition [{s:?}]"), 1, 5, || partition(&g, 8, s).unwrap());
    }

    // --- multi-PE end-to-end effect: strategy -> critical path
    use jgraph::accel::device::DeviceModel;
    use jgraph::accel::multipe::{InterconnectModel, MultiPeSimulator};
    use jgraph::sched::ParallelismPlan;
    use jgraph::translator::{pipeline::schedule, TranslatorKind};
    section("multi-PE critical path (4 PEs x 8 lanes, one full sweep)");
    for (gname, g) in &graphs {
        for s in STRATEGIES {
            let p = partition(g, 4, s).unwrap();
            let dev = DeviceModel::u200();
            let spec =
                schedule(TranslatorKind::JGraph, ParallelismPlan::new(8, 4), 20, dev.clock_hz);
            let mut sim = MultiPeSimulator::new(dev, spec, InterconnectModel::default());
            let step = sim.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &[0, 1, 2, 3]);
            println!(
                "  {:<22} {:>16} | critical {:>9} cyc | interconnect {:>8} cyc | \
                 crossing {:>6.1}% | PE spread {:.2}",
                gname,
                format!("{s:?}"),
                step.critical_cycles,
                step.interconnect_cycles,
                100.0 * step.crossing_msgs as f64 / g.num_edges() as f64,
                *step.pe_cycles.iter().max().unwrap() as f64
                    / (*step.pe_cycles.iter().min().unwrap() as f64).max(1.0),
            );
        }
    }
}
