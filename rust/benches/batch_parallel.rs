//! Sequential vs parallel multi-query sweep over one immutable binding:
//! the wall-clock case for `BoundPipeline::run_batch_parallel`. A 64-root
//! BFS sweep over an Erdős–Rényi graph (≥100k edges) is served by one
//! compiled design + one prepared graph, first with the sequential
//! `run_batch` loop, then fanned out over worker threads.
//!
//! Modeled per-query reports are identical either way (asserted); only
//! wall-clock changes. On a ≥4-core host the 4-worker sweep must be ≥2x
//! faster than sequential.

#[path = "harness.rs"]
mod harness;
use harness::*;

use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;

const NUM_QUERIES: usize = 64;

fn main() {
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let compiled = session.compile(&algorithms::bfs()).unwrap();
    // ER graph above the 100k-edge bar: enough per-query work that the
    // sweep is compute-bound, not thread-spawn-bound
    let graph = generate::erdos_renyi(50_000, 200_000, 77);
    let bound = compiled.load(&graph, PrepOptions::named("er-50k-200k")).unwrap();

    let csr = &bound.graph().csr;
    let n = csr.num_vertices() as u32;
    let queries: Vec<RunOptions> = (0..NUM_QUERIES)
        .map(|i| {
            let mut v = (i as u32 * 48_611) % n;
            while csr.degree(v) == 0 {
                v = (v + 1) % n;
            }
            RunOptions::from_root(v)
        })
        .collect();

    section(&format!("64-root BFS sweep, {} vertices / {} edges", n, csr.num_edges()));

    let d_seq = bench("sequential run_batch (1 thread)", 1, 5, || {
        let reports: Vec<_> = queries.iter().map(|q| bound.query(q).unwrap()).collect();
        reports.len()
    });

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let mut speedup_at_4 = 1.0;
    for workers in [2usize, 4, 8] {
        let d_par = bench(
            &format!("run_batch_parallel ({workers} workers)"),
            1,
            5,
            || bound.run_batch_parallel(&queries, workers).unwrap().len(),
        );
        let speedup = d_seq.as_secs_f64() / d_par.as_secs_f64();
        report_metric(&format!("speedup @ {workers} workers"), speedup, "x");
        if workers == 4 {
            speedup_at_4 = speedup;
        }
    }

    // equivalence spot-check: modeled reports must not depend on threading
    let seq = queries.iter().map(|q| bound.query(q).unwrap()).collect::<Vec<_>>();
    let par = bound.run_batch_parallel(&queries, 4).unwrap();
    for (p, s) in par.iter().zip(&seq) {
        assert_eq!(p.supersteps, s.supersteps);
        assert_eq!(p.edges_traversed, s.edges_traversed);
        assert_eq!(p.simulated_mteps.to_bits(), s.simulated_mteps.to_bits());
    }
    report_metric("reports identical seq vs par", 1.0, "(asserted)");

    // the acceptance gate only binds when the cores exist to win on
    if cores >= 4 {
        assert!(
            speedup_at_4 >= 2.0,
            "expected >= 2x with 4 workers on {cores} cores, measured {speedup_at_4:.2}x"
        );
        println!("OK: >= 2x wall-clock win with 4 workers on {cores} cores");
    } else {
        println!("note: only {cores} cores available; 2x gate needs >= 4");
    }
}
