//! Minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds fully offline (the build environment has no crates
//! registry). Implements the subset jgraph uses with anyhow-compatible
//! semantics:
//!
//! * [`Error`] / [`Result`] — an erased error with a context chain;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros;
//! * formatting: `{}` shows the outermost message, `{:#}` the full chain
//!   joined by `": "`, `{:?}` the message plus a `Caused by:` list.
//!
//! Like the real crate, [`Error`] intentionally does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion (`?` on any std error) coherent.

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        msgs.into_iter()
    }

    /// The innermost message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, m) in self.chain().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(m)?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            f.write_str("\n\nCaused by:")?;
            for (i, m) in self.chain().skip(1).enumerate() {
                write!(f, "\n    {i}: {m}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        fn build(e: &(dyn std::error::Error + 'static)) -> Error {
            Error { msg: e.to_string(), source: e.source().map(|s| Box::new(build(s))) }
        }
        build(&e)
    }
}

mod private {
    /// Sealed conversion used by [`super::Context`]: covers both std errors
    /// and [`super::Error`] itself (which deliberately is not a std error).
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> super::Error {
            super::Error::from(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
    }

    #[test]
    fn alternate_display_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn debug_shows_caused_by() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| format!("reading {}", "config"))
            .unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("reading config"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("file missing"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 42);
            }
            let n: u32 = "17".parse()?; // ParseIntError -> Error
            Ok(n)
        }
        assert_eq!(inner(false).unwrap(), 17);
        assert_eq!(inner(true).unwrap_err().to_string(), "boom 42");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            Ok(())
        }
        assert!(check(3).is_ok());
        assert!(check(30).unwrap_err().to_string().contains("too big"));
    }
}
