//! Integration tests for the `jgraph serve` daemon: the wire answers
//! must be *the same answers* the embedded API gives. 256 queries across
//! 2 graphs x 2 pipelines x 3 tenants go through a real TCP socket and
//! every modeled `RunReport` field must match a direct
//! `run_batch_parallel` bit for bit; residency stays under the LRU cap
//! with transparent reload; tenants at cap get typed rejects; drain
//! answers everything queued before exiting.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use jgraph::dsl::ParamSet;
use jgraph::engine::{RunOptions, RunReport, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::{PrepOptions, PreparedGraph};
use jgraph::serve::registry::program_by_name;
use jgraph::serve::wire::{Json, QueryRequest};
use jgraph::serve::{ServeClient, ServeConfig, ServeRegistry, Server};

const ER_VERTICES: usize = 512;
const GRID_SIDE: usize = 24;

fn er_edges() -> jgraph::graph::edgelist::EdgeList {
    generate::erdos_renyi(ER_VERTICES, 4_096, 13)
}

fn grid_edges() -> jgraph::graph::edgelist::EdgeList {
    generate::grid2d(GRID_SIDE, GRID_SIDE, 13)
}

fn vertices(graph: &str) -> u32 {
    if graph == "er" {
        ER_VERTICES as u32
    } else {
        (GRID_SIDE * GRID_SIDE) as u32
    }
}

fn start_server(max_resident: usize, config: ServeConfig) -> Server {
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let registry = Arc::new(ServeRegistry::new(session, max_resident));
    registry.register_edges("er", er_edges());
    registry.register_edges("grid", grid_edges());
    Server::start(config, registry).unwrap()
}

fn request(graph: &str, algo: &str, root: u32, tenant: &str) -> QueryRequest {
    QueryRequest {
        graph: graph.into(),
        algo: algo.into(),
        root,
        params: Vec::new(),
        direction: None,
        tenant: tenant.into(),
        max_supersteps: None,
        deadline_us: None,
    }
}

/// Every modeled (threading- and placement-independent) report field,
/// wire vs direct. Wall-clock fields (prep, functional exec) are
/// measured and legitimately differ; everything else must not.
fn assert_report_matches(wire: &Json, reference: &RunReport, what: &str) {
    let u = |key: &str| {
        wire.get(key)
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("{what}: missing numeric field {key}"))
    };
    let f = |key: &str| {
        wire.get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("{what}: missing float field {key}"))
    };
    assert_eq!(u("num_vertices"), reference.num_vertices as u64, "{what}: num_vertices");
    assert_eq!(u("num_edges"), reference.num_edges as u64, "{what}: num_edges");
    assert_eq!(u("supersteps"), reference.supersteps as u64, "{what}: supersteps");
    assert_eq!(u("push_supersteps"), reference.push_supersteps as u64, "{what}: push");
    assert_eq!(u("pull_supersteps"), reference.pull_supersteps as u64, "{what}: pull");
    assert_eq!(u("edges_traversed"), reference.edges_traversed, "{what}: edges_traversed");
    assert_eq!(u("shards"), reference.shards as u64, "{what}: shards");
    assert_eq!(u("auto_shards"), reference.auto_shards as u64, "{what}: auto_shards");
    assert_eq!(u("crossing_msgs"), reference.crossing_msgs, "{what}: crossing_msgs");
    assert_eq!(u("hdl_lines"), reference.hdl_lines as u64, "{what}: hdl_lines");
    assert_eq!(u("total_cycles"), reference.sim.cycles.total(), "{what}: total_cycles");
    for (key, value) in [
        ("query_seconds", reference.query_seconds),
        ("transfer_seconds", reference.transfer_seconds),
        ("exchange_seconds", reference.exchange_seconds),
        ("simulated_mteps", reference.simulated_mteps),
    ] {
        assert_eq!(f(key).to_bits(), value.to_bits(), "{what}: {key} must survive the wire");
    }
    let bound = wire.get("bound_params").unwrap_or_else(|| panic!("{what}: bound_params"));
    for (name, value) in &reference.bound_params {
        let wired = bound
            .get(name)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("{what}: bound param {name}"));
        assert_eq!(wired.to_bits(), value.to_bits(), "{what}: bound param {name}");
    }
}

/// The acceptance contract: 256 queries through the wire, one pipelined
/// connection per tenant, bit-identical to the embedded batch API.
#[test]
fn wire_reports_match_direct_batch_parallel_bit_for_bit() {
    let config = ServeConfig { batch_window: Duration::from_millis(5), ..Default::default() };
    let server = start_server(4, config);

    const TENANTS: [&str; 3] = ["alpha", "beta", "gamma"];
    const N: usize = 256;

    // plan the mix: 2 graphs x 2 algorithms x 3 tenants
    let mut plan: Vec<(usize, &str, &str, u32)> = Vec::with_capacity(N);
    for i in 0..N {
        let graph = if i % 2 == 0 { "er" } else { "grid" };
        let algo = if (i / 2) % 2 == 0 { "bfs" } else { "pagerank" };
        let root = (i as u32 * 37) % vertices(graph);
        plan.push((i % TENANTS.len(), graph, algo, root));
    }

    // send everything pipelined, one connection per tenant
    let mut clients: Vec<ServeClient> = TENANTS
        .iter()
        .map(|_| ServeClient::connect(server.local_addr()).unwrap())
        .collect();
    let mut per_client: Vec<Vec<(usize, &str, &str, u32)>> = vec![Vec::new(); TENANTS.len()];
    for &(tenant, graph, algo, root) in &plan {
        clients[tenant].send_query(&request(graph, algo, root, TENANTS[tenant])).unwrap();
        per_client[tenant].push((tenant, graph, algo, root));
    }

    // collect responses (in request order per connection)
    let mut wire_reports: Vec<((&str, &str, u32), Json)> = Vec::with_capacity(N);
    for (tenant, client) in clients.iter_mut().enumerate() {
        for &(_, graph, algo, root) in &per_client[tenant] {
            let resp = client.recv().unwrap();
            assert_eq!(
                resp.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "query ({graph}, {algo}, {root}) failed: {}",
                resp.render()
            );
            assert_eq!(resp.get("tenant").unwrap().as_str(), Some(TENANTS[tenant]));
            wire_reports.push(((graph, algo, root), resp.get("report").unwrap().clone()));
        }
    }
    assert_eq!(wire_reports.len(), N);

    // direct reference: same sources, same prep, same bind, the embedded
    // run_batch_parallel — no server in the loop
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let prepared: HashMap<&str, Arc<PreparedGraph>> = [("er", er_edges()), ("grid", grid_edges())]
        .into_iter()
        .map(|(name, el)| {
            (name, Arc::new(PreparedGraph::prepare(&el, &PrepOptions::named(name)).unwrap()))
        })
        .collect();
    let mut reference: HashMap<(&str, &str, u32), RunReport> = HashMap::new();
    for graph in ["er", "grid"] {
        for algo in ["bfs", "pagerank"] {
            let mut roots: Vec<u32> = plan
                .iter()
                .filter(|(_, g, a, _)| *g == graph && *a == algo)
                .map(|&(_, _, _, root)| root)
                .collect();
            roots.sort_unstable();
            roots.dedup();
            let pipeline = session.compile(&program_by_name(algo).unwrap()).unwrap();
            let bound = pipeline.bind(prepared[graph].clone()).unwrap();
            let queries: Vec<RunOptions> = roots
                .iter()
                .map(|&root| RunOptions { root, params: ParamSet::new(), ..Default::default() })
                .collect();
            let reports = bound.run_batch_parallel(&queries, 2).unwrap();
            for (&root, report) in roots.iter().zip(reports) {
                reference.insert((graph, algo, root), report);
            }
        }
    }

    for ((graph, algo, root), wire) in &wire_reports {
        let what = format!("({graph}, {algo}, root {root})");
        assert_report_matches(wire, &reference[&(*graph, *algo, *root)], &what);
    }

    // the daemon's accounting saw all of it
    let stats = clients[0].stats().unwrap();
    assert_eq!(stats.get("served").unwrap().as_u64(), Some(N as u64));
    assert_eq!(stats.get("errors").unwrap().as_u64(), Some(0));
    assert!(stats.get("batches").unwrap().as_u64().unwrap() >= 4, "4 bindings => >= 4 sweeps");
    assert!(stats.get("mean_batch_occupancy").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(stats.get("tenant_rejects").unwrap().as_u64(), Some(0));

    drop(clients);
    server.join().unwrap();
}

/// Residency never exceeds the cap; evicted graphs reload transparently
/// and keep giving the same modeled answers.
#[test]
fn lru_cap_bounds_residency_and_reloads_transparently() {
    let server = start_server(1, ServeConfig::default());
    let mut c = ServeClient::connect(server.local_addr()).unwrap();
    let mut first_er_supersteps = None;
    for round in 0..3 {
        for graph in ["er", "grid"] {
            let resp = c.query(&request(graph, "bfs", 3, "solo")).unwrap();
            assert_eq!(
                resp.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "round {round} on {graph}: {}",
                resp.render()
            );
            let supersteps =
                resp.get("report").unwrap().get("supersteps").unwrap().as_u64().unwrap();
            if graph == "er" {
                // reloads after eviction are deterministic: same graph,
                // same root, same modeled traversal
                match first_er_supersteps {
                    None => first_er_supersteps = Some(supersteps),
                    Some(first) => assert_eq!(supersteps, first, "reload drifted"),
                }
            }
            let stats = c.stats().unwrap();
            let resident = stats.get("resident_graphs").unwrap().as_u64().unwrap();
            assert!(resident <= 1, "cap 1 exceeded: {resident} resident");
        }
    }
    let stats = c.stats().unwrap();
    // 6 alternating loads against a cap of 1: every switch evicts
    assert!(
        stats.get("evictions").unwrap().as_u64().unwrap() >= 5,
        "alternating bindings must churn the LRU: {}",
        stats.render()
    );
    assert_eq!(stats.get("served").unwrap().as_u64(), Some(6));
    drop(c);
    server.join().unwrap();
}

/// A tenant at its cap gets the typed reject, the wire stays usable, and
/// capacity returns once the in-flight query finishes.
#[test]
fn tenant_over_cap_gets_typed_reject_and_recovers() {
    // long window: the first admitted query parks in the batcher,
    // pinning the tenant at its cap while the next two arrive
    let config = ServeConfig {
        batch_window: Duration::from_millis(300),
        tenant_caps: vec![("metered".into(), 1)],
        ..Default::default()
    };
    let server = start_server(4, config);
    let mut c = ServeClient::connect(server.local_addr()).unwrap();
    for root in 0..3 {
        c.send_query(&request("er", "bfs", root, "metered")).unwrap();
    }
    let (mut served, mut rejected) = (0, 0);
    for _ in 0..3 {
        let resp = c.recv().unwrap();
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            served += 1;
        } else {
            let kind = resp.get("error").unwrap().get("kind").unwrap().as_str().unwrap();
            assert_eq!(kind, "tenant_over_cap", "{}", resp.render());
            rejected += 1;
        }
    }
    assert_eq!((served, rejected), (1, 2));
    // an unrelated tenant was never blocked, and the capped tenant
    // recovers once its query completes
    let resp = c.query(&request("er", "bfs", 9, "other")).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    let resp = c.query(&request("er", "bfs", 9, "metered")).unwrap();
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("tenant_rejects").unwrap().as_u64(), Some(2));
    let metered = stats.get("tenants").unwrap().get("metered").unwrap();
    assert_eq!(metered.get("cap").unwrap().as_u64(), Some(1));
    assert_eq!(metered.get("rejected").unwrap().as_u64(), Some(2));
    drop(c);
    server.join().unwrap();
}

/// Drain: everything admitted before the shutdown op still gets its
/// response, then every daemon thread joins.
#[test]
fn drain_answers_queued_queries_then_joins() {
    let config = ServeConfig { batch_window: Duration::from_millis(50), ..Default::default() };
    let server = start_server(4, config);
    let mut c = ServeClient::connect(server.local_addr()).unwrap();
    for i in 0..8u32 {
        let graph = if i % 2 == 0 { "er" } else { "grid" };
        c.send_query(&request(graph, "bfs", i, "drainer")).unwrap();
    }
    // the shutdown op lands behind the 8 queries on the same connection,
    // so all of them are admitted before the drain begins
    c.send_line(r#"{"op":"shutdown"}"#).unwrap();
    for i in 0..8 {
        let resp = c.recv().unwrap();
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "queued query {i} lost in drain: {}",
            resp.render()
        );
    }
    let ack = c.recv().unwrap();
    assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(ack.get("op").unwrap().as_str(), Some("shutdown"));
    // post-drain queries get the typed reject (if the daemon still
    // answers at all — the reader may already be EOF-ed by join)
    if c.send_query(&request("er", "bfs", 0, "late")).is_ok() {
        if let Ok(resp) = c.recv() {
            assert_eq!(
                resp.get("error").unwrap().get("kind").unwrap().as_str(),
                Some("draining")
            );
        }
    }
    drop(c);
    server.join().unwrap();
}
