//! Integration for the compile-once / run-many lifecycle:
//! `Session::compile` → `CompiledPipeline::load` → `BoundPipeline::run`.
//!
//! Covers the three contract points of the redesign: (1) repeated runs on
//! one bound pipeline are exactly equivalent to repeated one-shot
//! `Executor::run`s, (2) `run_batch` is exactly equivalent to sequential
//! runs, and (3) builder → `compile` failures surface as typed
//! [`CompileError`] values, not panics.

use jgraph::dsl::algorithms;
use jgraph::dsl::apply::ApplyExpr;
use jgraph::dsl::builder::GasProgramBuilder;
use jgraph::dsl::program::{ReduceOp, StateType, Writeback};
use jgraph::engine::{CompileError, RunOptions, RunReport, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::{PrepOptions, PreparedGraph};
use jgraph::prep::reorder::ReorderStrategy;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::Translator;

fn software_session() -> Session {
    Session::new(SessionConfig { use_xla: false, ..Default::default() })
}

/// The deterministic result surface of a run (timing fields excluded).
fn result_key(r: &RunReport) -> (u32, u64, String, usize, usize, u64) {
    (
        r.supersteps,
        r.edges_traversed,
        format!("{:.12e}", r.simulated_mteps),
        r.num_vertices,
        r.num_edges,
        r.sim.cycles.total(),
    )
}

#[test]
#[allow(deprecated)]
fn bound_pipeline_runs_match_fresh_executor_runs() {
    let g = generate::rmat(10, 20_000, 0.57, 0.19, 0.19, 11);
    let program = algorithms::wcc();

    // new lifecycle: compile once, load once, run twice
    let session = software_session();
    let compiled = session.compile(&program).unwrap();
    let mut bound = compiled
        .load(&g, PrepOptions::named("rmat10").with_reorder(ReorderStrategy::DegreeSort))
        .unwrap();
    let n1 = bound.run(&RunOptions::default()).unwrap();
    let n2 = bound.run(&RunOptions::default()).unwrap();

    // legacy shim: everything re-paid per call
    use jgraph::engine::{Executor, ExecutorConfig};
    let design = Translator::jgraph().translate(&program).unwrap();
    let mut run_old = || {
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            reorder: Some(ReorderStrategy::DegreeSort),
            graph_name: "rmat10".into(),
            ..Default::default()
        });
        ex.run(&program, &design, &g).unwrap()
    };
    let o1 = run_old();
    let o2 = run_old();

    // identical result surface across all four runs
    assert_eq!(result_key(&n1), result_key(&n2), "bound runs must be deterministic");
    assert_eq!(result_key(&o1), result_key(&o2), "executor runs must be deterministic");
    assert_eq!(result_key(&n1), result_key(&o1), "lifecycle must equal the one-shot shim");
    assert_eq!(n1.graph_name, o1.graph_name);
    assert_eq!(n1.translator, o1.translator);
    assert_eq!(n1.hdl_lines, o1.hdl_lines);
}

#[test]
fn run_batch_equals_sequential_runs() {
    let g = generate::rmat(10, 30_000, 0.57, 0.19, 0.19, 21);
    let session = software_session();
    let compiled = session.compile(&algorithms::bfs()).unwrap();

    let n = g.num_vertices as u32;
    let queries: Vec<RunOptions> =
        (0..8u32).map(|i| RunOptions::from_root((i * 977) % n)).collect();

    let mut batch_bound = compiled.load(&g, PrepOptions::named("rmat10")).unwrap();
    let batch = batch_bound.run_batch(&queries).unwrap();

    let mut seq_bound = compiled.load(&g, PrepOptions::named("rmat10")).unwrap();
    let sequential: Vec<_> =
        queries.iter().map(|q| seq_bound.run(q).unwrap()).collect();

    assert_eq!(batch.len(), sequential.len());
    for (b, s) in batch.iter().zip(&sequential) {
        assert_eq!(result_key(b), result_key(s));
    }
    assert_eq!(batch_bound.queries_run(), queries.len() as u64);
}

#[test]
fn builder_compile_surfaces_typed_validation_errors() {
    let session = software_session();
    // Reduce(Sum) feeding the visited gate is rejected by DSL validation
    let err = GasProgramBuilder::new("accumulating-bfs")
        .state(StateType::I32)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::IfUnvisited)
        .compile(&session)
        .unwrap_err();
    match &err {
        CompileError::InvalidProgram { program, reason } => {
            assert_eq!(program, "accumulating-bfs");
            assert!(reason.contains("Reduce(Sum)"), "{reason}");
        }
        other => panic!("expected InvalidProgram, got {other:?}"),
    }
    // a missing Apply is also an InvalidProgram, not a panic
    let err = GasProgramBuilder::new("no-apply").compile(&session).unwrap_err();
    assert!(matches!(err, CompileError::InvalidProgram { .. }), "{err:?}");
}

#[test]
fn oversized_design_is_a_typed_does_not_fit() {
    let session = software_session();
    let translator = Translator::jgraph().with_plan(ParallelismPlan::new(512, 8));
    let err = session.compile_with(translator, &algorithms::bfs()).unwrap_err();
    match err {
        CompileError::DoesNotFit { program, translator, device } => {
            assert_eq!(program, "bfs");
            assert_eq!(translator, "FAgraph");
            assert!(device.contains("u200"));
        }
        other => panic!("expected DoesNotFit, got {other:?}"),
    }
}

#[test]
fn prep_options_carry_the_graph_name() {
    let g = generate::erdos_renyi(120, 900, 6);
    let session = software_session();
    let compiled = session.compile(&algorithms::bfs()).unwrap();
    let mut bound = compiled.load(&g, PrepOptions::named("my-graph")).unwrap();
    let r = bound.run(&RunOptions::default()).unwrap();
    assert_eq!(r.graph_name, "my-graph");
    assert_eq!(bound.graph().name, "my-graph");
}

#[test]
fn setup_is_paid_once_and_reported_consistently() {
    let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 31);
    let session = software_session();
    let compiled = session.compile(&algorithms::sssp()).unwrap();
    let mut bound = compiled
        .load(&g, PrepOptions::named("rmat9").with_reorder(ReorderStrategy::BfsLocality))
        .unwrap();
    let r1 = bound.run(&RunOptions::default()).unwrap();
    let r2 = bound.run(&RunOptions::default()).unwrap();
    // one-time periods are byte-identical across queries on one binding
    assert_eq!(r1.prep_seconds, r2.prep_seconds);
    assert_eq!(r1.compile_seconds, r2.compile_seconds);
    assert_eq!(r1.deploy_seconds, r2.deploy_seconds);
    assert_eq!(r1.setup_seconds, r2.setup_seconds);
    // the report decomposition holds: rt = setup + query,
    // setup = prep + compile + deploy,
    // query = sim exec + functional exec + read-back DMA
    for r in [&r1, &r2] {
        assert!((r.setup_seconds - (r.prep_seconds + r.compile_seconds + r.deploy_seconds))
            .abs()
            < 1e-12);
        assert!((r.rt_seconds - (r.setup_seconds + r.query_seconds)).abs() < 1e-12);
        assert!(
            (r.query_seconds
                - (r.sim_exec_seconds + r.functional_exec_seconds + r.transfer_seconds))
                .abs()
                < 1e-12
        );
        assert!(r.transfer_seconds > 0.0, "read-back DMA must be part of the query cost");
    }
    assert!(bound.setup_seconds() >= jgraph::engine::executor::FLASH_SECONDS);
}

#[test]
fn prepared_graph_is_shareable_across_pipelines() {
    let g = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 41);
    let prepared =
        std::sync::Arc::new(PreparedGraph::prepare(&g, &PrepOptions::named("shared")).unwrap());
    let session = software_session();
    let bfs = session.compile(&algorithms::bfs()).unwrap();
    let wcc = session.compile(&algorithms::wcc()).unwrap();
    let r_bfs = bfs.run_on(&prepared, &RunOptions::default()).unwrap();
    let r_wcc = wcc.run_on(&prepared, &RunOptions::default()).unwrap();
    assert_eq!(r_bfs.graph_name, "shared");
    assert_eq!(r_wcc.graph_name, "shared");
    // the prepared layout is identical for both pipelines
    assert_eq!(r_bfs.num_edges, r_wcc.num_edges);
    assert!(r_bfs.supersteps > 0 && r_wcc.supersteps > 0);
}

/// The `rt = setup + query` identity must hold on **both** functional
/// paths. With AOT artifacts absent, `use_xla: true` falls back to the
/// software oracle — the identity (and the test) still holds; with
/// artifacts built, the same assertions cover the XLA path's
/// `functional_exec_seconds > 0` case.
#[test]
fn rt_identity_holds_on_both_functional_paths() {
    let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 13);
    for use_xla in [false, true] {
        let session = Session::new(SessionConfig { use_xla, ..Default::default() });
        let compiled = session.compile(&algorithms::bfs()).unwrap();
        let mut bound = compiled.load(&g, PrepOptions::named("rmat9")).unwrap();
        let r = bound.run(&RunOptions { use_xla, ..RunOptions::default() }).unwrap();
        assert!(
            (r.rt_seconds - (r.setup_seconds + r.query_seconds)).abs() < 1e-12,
            "use_xla={use_xla} path={:?}: rt {} != setup {} + query {}",
            r.functional_path,
            r.rt_seconds,
            r.setup_seconds,
            r.query_seconds
        );
        assert!(
            (r.query_seconds
                - (r.sim_exec_seconds + r.functional_exec_seconds + r.transfer_seconds))
                .abs()
                < 1e-12,
            "use_xla={use_xla}: query decomposition broken"
        );
    }
}

/// Satellite regression: the iteration-cap safety net must abort the run
/// on the integration path, not be silently dropped.
#[test]
fn iteration_cap_hit_errors_out_of_the_lifecycle() {
    let session = software_session();
    let compiled = session.compile(&algorithms::bfs()).unwrap();
    let g = generate::chain(100); // BFS from 0 needs ~100 supersteps
    let mut bound = compiled.load(&g, PrepOptions::named("chain")).unwrap();
    let err = bound.run(&RunOptions::from_root(0).with_max_supersteps(5)).unwrap_err();
    assert!(err.to_string().contains("iteration cap 5 hit"), "{err}");
    // legacy batch wrapper propagates too
    let queries = vec![RunOptions::from_root(0).with_max_supersteps(5)];
    assert!(bound.run_batch(&queries).is_err());
    // and the binding still serves well-behaved queries afterwards
    assert!(bound.run(&RunOptions::from_root(0)).is_ok());
}

/// Satellite: `run_batch_parallel` must be observationally equivalent to
/// sequential `run_batch` for a 32-root sweep — per-query reports and the
/// merged transfer ledger alike.
#[test]
fn run_batch_parallel_equals_sequential_for_32_root_sweep() {
    let g = generate::rmat(11, 140_000, 0.57, 0.19, 0.19, 29);
    let session = software_session();
    let compiled = session.compile(&algorithms::bfs()).unwrap();

    let n = g.num_vertices as u32;
    let queries: Vec<RunOptions> =
        (0..32u32).map(|i| RunOptions::from_root((i * 2_741) % n)).collect();

    let mut seq_bound = compiled.load(&g, PrepOptions::named("rmat11")).unwrap();
    let sequential = seq_bound.run_batch(&queries).unwrap();

    let par_bound = compiled.load(&g, PrepOptions::named("rmat11")).unwrap();
    let parallel = par_bound.run_batch_parallel(&queries, 4).unwrap();

    assert_eq!(parallel.len(), 32);
    for (i, (p, q)) in parallel.iter().zip(&sequential).enumerate() {
        assert_eq!(p.supersteps, q.supersteps, "root #{i}");
        assert_eq!(p.edges_traversed, q.edges_traversed, "root #{i}");
        assert_eq!(
            p.simulated_mteps.to_bits(),
            q.simulated_mteps.to_bits(),
            "root #{i}: modeled throughput must not depend on threading"
        );
        assert_eq!(result_key(p), result_key(q), "root #{i}");
        assert_eq!(p.transfer_seconds.to_bits(), q.transfer_seconds.to_bits(), "root #{i}");
    }
    // verify-path equivalence: the oracle values behind each report are
    // the same because supersteps/edges/cycles all match per root (checked
    // above); the merged DMA ledger must also be bit-identical
    assert_eq!(par_bound.comm().bytes_moved(), seq_bound.comm().bytes_moved());
    assert_eq!(
        par_bound.comm().transfer_seconds().to_bits(),
        seq_bound.comm().transfer_seconds().to_bits()
    );
    assert_eq!(par_bound.queries_run(), 32);
}

#[test]
fn trace_written_per_query_on_bound_pipeline() {
    let g = generate::rmat(9, 4_000, 0.57, 0.19, 0.19, 33);
    let session = software_session();
    let compiled = session.compile(&algorithms::bfs()).unwrap();
    let mut bound = compiled.load(&g, PrepOptions::named("rmat9")).unwrap();
    let path = std::env::temp_dir().join("jgraph_session_trace.csv");
    let r = bound
        .run(&RunOptions::default().with_trace(&path))
        .unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    assert_eq!(csv.lines().count() as u32, r.supersteps + 1);
    // a traceless query on the same binding leaves the file untouched
    let before = std::fs::metadata(&path).unwrap().modified().unwrap();
    bound.run(&RunOptions::default()).unwrap();
    assert_eq!(std::fs::metadata(&path).unwrap().modified().unwrap(), before);
}
