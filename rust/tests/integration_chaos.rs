//! Chaos acceptance for the fault-tolerant query core (ISSUE 10): a
//! 256-query multi-tenant sweep through a real TCP socket against a
//! daemon armed with a deterministic fault plan. The plan injects
//! panics, transfer errors, and compile failures; extra queries carry
//! already-expired deadlines. The contract:
//!
//! * every query unaffected by a fault answers `ok:true` with a report
//!   **bit-identical** to the same query against a fault-free daemon
//!   (transient-faulted queries retry to success and must match too —
//!   the modeled numbers are attempt-independent);
//! * faulted queries earn *typed* rejects (`deadline_exceeded`,
//!   `compile_failed`), never a dead daemon or a hung connection;
//! * the stats counters prove the harness actually fired;
//! * drain-then-join completes while the plan is still injecting.

use std::sync::Arc;
use std::time::Duration;

use jgraph::engine::{Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::sched::FaultPlan;
use jgraph::serve::wire::{Json, QueryRequest};
use jgraph::serve::{ServeClient, ServeConfig, ServeRegistry, Server};

const VERTICES: usize = 512;
const N: u32 = 256;
const TENANTS: [&str; 4] = ["t0", "t1", "t2", "t3"];

/// Six transient faults (attempt-0-keyed, so one retry clears each:
/// `exec` tokens and `commit` tokens are both `root | attempt << 32`)
/// plus a compile failure keyed to the `wcc` algorithm. With the three
/// expired-deadline queries below, that is >= 8 injected faults across
/// four classes: panic, exec/transfer error, compile failure, deadline.
const PLAN: &str = "seed=11;panic@exec#5;exec_fail@exec#23;transfer_error@commit#57;\
                    exec_fail@exec#91;panic@exec#133;transfer_error@commit#171;\
                    compile_fail@compile#wcc";

fn start_server(fault_plan: Option<Arc<FaultPlan>>) -> Server {
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let registry = Arc::new(ServeRegistry::new(session, 4));
    registry.register_edges("er", generate::erdos_renyi(VERTICES, 4_096, 13));
    let config = ServeConfig {
        batch_window: Duration::from_millis(2),
        fault_plan,
        ..Default::default()
    };
    Server::start(config, registry).unwrap()
}

fn request(algo: &str, root: u32, tenant: &str) -> QueryRequest {
    QueryRequest {
        graph: "er".into(),
        algo: algo.into(),
        root,
        params: Vec::new(),
        direction: None,
        tenant: tenant.into(),
        max_supersteps: None,
        deadline_us: None,
    }
}

/// Drive the canonical multi-tenant sweep — roots `0..N`, tenant by
/// `root % 4`, one pipelined connection per tenant — and hand back every
/// report in root order.
fn run_sweep(server: &Server) -> Vec<Json> {
    let mut clients: Vec<ServeClient> = TENANTS
        .iter()
        .map(|_| ServeClient::connect(server.local_addr()).unwrap())
        .collect();
    let mut per_client: Vec<Vec<u32>> = vec![Vec::new(); TENANTS.len()];
    for root in 0..N {
        let t = (root as usize) % TENANTS.len();
        clients[t].send_query(&request("bfs", root, TENANTS[t])).unwrap();
        per_client[t].push(root);
    }
    let mut reports: Vec<Option<Json>> = (0..N).map(|_| None).collect();
    for (t, client) in clients.iter_mut().enumerate() {
        for &root in &per_client[t] {
            let resp = client.recv().unwrap();
            assert_eq!(
                resp.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "root {root} (tenant {}) failed: {}",
                TENANTS[t],
                resp.render()
            );
            reports[root as usize] = Some(resp.get("report").unwrap().clone());
        }
    }
    reports.into_iter().map(|r| r.unwrap()).collect()
}

/// Modeled (wall-clock-independent) report fields, two wire answers
/// compared bit for bit — f64s via `to_bits`, so "close" is a failure.
fn assert_reports_identical(chaos: &Json, baseline: &Json, what: &str) {
    for key in [
        "num_vertices",
        "num_edges",
        "supersteps",
        "push_supersteps",
        "pull_supersteps",
        "edges_traversed",
        "shards",
        "auto_shards",
        "crossing_msgs",
        "hdl_lines",
        "total_cycles",
    ] {
        let get = |j: &Json| {
            j.get(key)
                .and_then(|v| v.as_u64())
                .unwrap_or_else(|| panic!("{what}: missing numeric field {key}"))
        };
        assert_eq!(get(chaos), get(baseline), "{what}: {key} diverged under faults");
    }
    for key in ["query_seconds", "transfer_seconds", "exchange_seconds", "simulated_mteps"] {
        let get = |j: &Json| {
            j.get(key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("{what}: missing float field {key}"))
        };
        assert_eq!(
            get(chaos).to_bits(),
            get(baseline).to_bits(),
            "{what}: {key} must be bit-identical under faults"
        );
    }
}

#[test]
fn chaos_sweep_is_bit_identical_and_the_daemon_survives() {
    // ---- baseline: the same 256 queries with no plan armed ----------
    let clean = start_server(None);
    let baseline = run_sweep(&clean);
    let mut c = ServeClient::connect(clean.local_addr()).unwrap();
    c.shutdown().unwrap();
    drop(c);
    clean.join().unwrap();

    // ---- chaos: same sweep, plan armed ------------------------------
    let plan = Arc::new(FaultPlan::parse(PLAN).unwrap());
    let server = start_server(Some(plan.clone()));
    let reports = run_sweep(&server);
    for (root, (chaos, base)) in reports.iter().zip(&baseline).enumerate() {
        assert_reports_identical(chaos, base, &format!("root {root}"));
    }

    let mut c = ServeClient::connect(server.local_addr()).unwrap();

    // expired deadlines: typed reject, partial accounting in the message
    for root in [300u32, 301, 302] {
        let mut q = request("bfs", root, "deadliner");
        q.deadline_us = Some(0);
        let resp = c.query(&q).unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false), "{}", resp.render());
        let err = resp.get("error").unwrap();
        assert_eq!(err.get("kind").unwrap().as_str(), Some("deadline_exceeded"));
        assert!(
            err.get("message").unwrap().as_str().unwrap().contains("deadline exceeded after"),
            "the reject reports how far the query got: {}",
            resp.render()
        );
    }

    // injected compile failures: typed, keyed by algorithm, bfs unharmed
    for root in 0..3u32 {
        let resp = c.query(&request("wcc", root, "compiler")).unwrap();
        assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false), "{}", resp.render());
        assert_eq!(
            resp.get("error").unwrap().get("kind").unwrap().as_str(),
            Some("compile_failed"),
            "{}",
            resp.render()
        );
    }

    // the counters prove the harness fired and retries absorbed it all
    let stats = c.stats().unwrap();
    let n = |key: &str| {
        stats
            .get(key)
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("stats missing {key}: {}", stats.render()))
    };
    assert_eq!(n("served"), N as u64, "every sweep query was answered ok");
    assert!(n("faults_injected") >= 8, "plan must have fired: {}", stats.render());
    assert!(n("retries_attempted") >= 6, "six transient faults retried: {}", stats.render());
    assert_eq!(n("retries_exhausted"), 0, "attempt-0 faults never exhaust the budget");
    assert!(n("panics_caught") >= 2, "two injected panics were fenced: {}", stats.render());
    assert!(n("deadline_exceeded") >= 3, "three expired deadlines: {}", stats.render());
    assert_eq!(
        stats.get("fault_plan").unwrap().as_str(),
        Some(PLAN),
        "stats names the armed plan"
    );
    assert_eq!(plan.injected_total(), n("faults_injected"), "gauge mirrors the plan");

    // ---- drain under active injection -------------------------------
    // a pipelined burst that re-trips the attempt-0 fault tokens (the
    // plan is pure in (seam, token), so roots 5 and 23 fault again),
    // then the shutdown op behind it: everything queued still answers,
    // then every daemon thread joins.
    for root in 0..32u32 {
        c.send_query(&request("bfs", root, "drainer")).unwrap();
    }
    c.send_line(r#"{"op":"shutdown"}"#).unwrap();
    for root in 0..32u32 {
        let resp = c.recv().unwrap();
        assert_eq!(
            resp.get("ok").and_then(|v| v.as_bool()),
            Some(true),
            "burst root {root} lost in drain: {}",
            resp.render()
        );
    }
    let ack = c.recv().unwrap();
    assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(ack.get("op").unwrap().as_str(), Some("shutdown"));
    drop(c);
    server.join().unwrap();
}
