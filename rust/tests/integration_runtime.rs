//! Integration over the real AOT artifacts: loads the HLO text produced by
//! `make artifacts`, compiles it on the PJRT CPU client, executes
//! supersteps from rust, and cross-checks every canonical algorithm
//! against the software GAS oracle on real graph workloads.
//!
//! These tests require `artifacts/manifest.tsv` **and** a build with the
//! real PJRT bindings (`--features pjrt`); when either is missing each
//! test skips (prints a note and returns) rather than failing — the
//! default checkout has neither, and the rest of the suite covers the
//! software path.
#![allow(deprecated)] // the executor shim's XLA path is covered here too

use std::sync::Arc;

use jgraph::dsl::algorithms;
use jgraph::dsl::program::EdgeOpKind;
use jgraph::engine::{gas, xla_engine, Executor, ExecutorConfig, FunctionalPath};
use jgraph::graph::csr::Csr;
use jgraph::graph::generate;
use jgraph::runtime::{Buffer, KernelRegistry};
use jgraph::translator::Translator;

/// The shared registry, or `None` when artifacts are not built in this
/// checkout (every caller skips in that case).
fn registry() -> Option<Arc<KernelRegistry>> {
    // PJRT handles are not Send/Sync (Rc internals), so the cache is
    // per-test-thread rather than a process-wide OnceLock.
    thread_local! {
        static REG: std::cell::OnceCell<Option<Arc<KernelRegistry>>> =
            const { std::cell::OnceCell::new() };
    }
    REG.with(|c| {
        c.get_or_init(|| match KernelRegistry::open_default() {
            Ok(r) => Some(Arc::new(r)),
            Err(e) => {
                eprintln!("skipping AOT-artifact test: {e:#}");
                None
            }
        })
        .clone()
    })
}

macro_rules! registry_or_skip {
    () => {
        match registry() {
            Some(r) => r,
            None => return,
        }
    };
}

#[test]
fn registry_loads_and_reports_platform() {
    let reg = registry_or_skip!();
    assert!(reg.platform().to_lowercase().contains("cpu") || !reg.platform().is_empty());
    assert!(reg.manifest.artifacts.len() >= 20, "5 algos x 4 buckets");
}

#[test]
fn every_canonical_kind_matches_oracle_on_random_graph() {
    let reg = registry_or_skip!();
    let g = generate::rmat(8, 3_000, 0.57, 0.19, 0.19, 77);
    let csr = Csr::from_edgelist(&g);
    for kind in EdgeOpKind::all() {
        let xla = match xla_engine::run(&reg, kind, &csr, 0, 1e-7) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("skipping {kind:?}: {e:#}");
                return; // stub PJRT backend: artifacts exist but cannot load
            }
        };
        let program = match kind {
            EdgeOpKind::Bfs => algorithms::bfs(),
            EdgeOpKind::Pr => algorithms::pagerank_with(0.85, 1e-7),
            EdgeOpKind::Sssp => algorithms::sssp(),
            EdgeOpKind::Wcc => algorithms::wcc(),
            EdgeOpKind::Spmv => algorithms::spmv(),
        };
        let oracle = gas::run(&program, &csr, 0, |_| {}).unwrap();
        let dev = xla_engine::max_deviation(&xla.values, &oracle.values);
        assert!(dev < 1e-3, "{kind:?}: deviation {dev}");
    }
}

#[test]
fn bucket_selection_pads_correctly() {
    let reg = registry_or_skip!();
    // a graph that fits tiny exactly at the boundary
    let g = generate::erdos_renyi(256, 4_096, 3);
    let csr = Csr::from_edgelist(&g);
    let Ok(exe) = reg.for_graph("bfs", csr.num_vertices(), csr.num_edges()) else {
        eprintln!("skipping: PJRT backend unavailable");
        return;
    };
    assert_eq!(exe.meta.bucket, "tiny");
    // one vertex more must spill to the next bucket
    let exe2 = reg.for_graph("bfs", 257, 4_096).unwrap();
    assert_eq!(exe2.meta.bucket, "small");
}

#[test]
fn executable_rejects_wrong_abi() {
    let reg = registry_or_skip!();
    let Ok(exe) = reg.for_bucket("wcc", "tiny") else {
        eprintln!("skipping: PJRT backend unavailable");
        return;
    };
    // wrong arity
    assert!(exe.run(&[Buffer::I32(vec![0; 256])]).is_err());
    // wrong length
    let bad = vec![
        Buffer::I32(vec![0; 13]), // label should be 256
        Buffer::I32(vec![0; 4096]),
        Buffer::I32(vec![0; 4096]),
        Buffer::I32(vec![0; 1]),
    ];
    assert!(exe.run(&bad).is_err());
    // wrong dtype
    let bad2 = vec![
        Buffer::F32(vec![0.0; 256]),
        Buffer::I32(vec![0; 4096]),
        Buffer::I32(vec![0; 4096]),
        Buffer::I32(vec![0; 1]),
    ];
    assert!(exe.run(&bad2).is_err());
}

#[test]
fn executor_uses_xla_path_and_verifies() {
    let reg = registry_or_skip!();
    if reg.for_bucket("bfs", "tiny").is_err() {
        eprintln!("skipping: PJRT backend unavailable");
        return;
    }
    let g = generate::email_eu_core_like(7);
    let program = algorithms::bfs();
    let design = Translator::jgraph().translate(&program).unwrap();
    let mut ex = Executor::new(ExecutorConfig {
        graph_name: "email".into(),
        ..Default::default()
    })
    .with_registry(reg);
    let r = ex.run(&program, &design, &g).unwrap();
    assert_eq!(r.functional_path, FunctionalPath::Xla);
    assert_eq!(r.oracle_deviation, Some(0.0), "BFS is integer-exact");
    assert!(r.functional_exec_seconds > 0.0);
}

#[test]
fn session_pipeline_uses_xla_path_and_verifies() {
    use jgraph::engine::{RunOptions, Session, SessionConfig};
    use jgraph::prep::prepared::PrepOptions;
    let reg = registry_or_skip!();
    if reg.for_bucket("bfs", "tiny").is_err() {
        eprintln!("skipping: PJRT backend unavailable");
        return;
    }
    let g = generate::email_eu_core_like(7);
    let session = Session::new(SessionConfig::default()).with_registry(reg);
    let compiled = session.compile(&algorithms::bfs()).unwrap();
    assert!(compiled.has_xla());
    let mut bound = compiled.load(&g, PrepOptions::named("email")).unwrap();
    // the AOT lookup happened at compile; both queries ride the XLA path
    for root in [0u32, 5] {
        let r = bound.run(&RunOptions::from_root(root)).unwrap();
        assert_eq!(r.functional_path, FunctionalPath::Xla);
        assert_eq!(r.oracle_deviation, Some(0.0), "BFS is integer-exact");
    }
}

#[test]
fn bfs_xla_on_chain_has_exact_levels() {
    let reg = registry_or_skip!();
    // deterministic shape: chain BFS levels are 0..n-1
    let g = generate::chain(200);
    let csr = Csr::from_edgelist(&g);
    let xla = match xla_engine::run(&reg, EdgeOpKind::Bfs, &csr, 0, 0.0) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    for (v, &lvl) in xla.values.iter().enumerate() {
        assert_eq!(lvl as usize, v);
    }
    assert_eq!(xla.edges_traversed, 199);
}

#[test]
fn spmv_xla_matches_dense_matvec() {
    let reg = registry_or_skip!();
    let mut el = jgraph::graph::edgelist::EdgeList::default();
    el.push(0, 1, 2.0);
    el.push(0, 2, 3.0);
    el.push(1, 2, 4.0);
    el.num_vertices = 3;
    let csr = Csr::from_edgelist(&el);
    let xla = match xla_engine::run(&reg, EdgeOpKind::Spmv, &csr, 0, 0.0) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    assert_eq!(xla.values, vec![0.0, 2.0, 7.0]);
}

#[test]
fn pagerank_xla_mass_conserved() {
    let reg = registry_or_skip!();
    let g = generate::rmat(9, 8_000, 0.57, 0.19, 0.19, 13);
    let csr = Csr::from_edgelist(&g);
    let xla = match xla_engine::run(&reg, EdgeOpKind::Pr, &csr, 0, 1e-8) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    let mass: f64 = xla.values.iter().sum();
    assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
}
