//! Integration: DSL → translate → module graph / HDL / resources, across
//! every library algorithm × every translator flow.

use jgraph::accel::device::DeviceModel;
use jgraph::dsl::algorithms;
use jgraph::dsl::ops::HwModule;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::{Translator, TranslatorKind};

#[test]
fn every_algorithm_translates_through_every_flow() {
    for program in algorithms::all() {
        for kind in TranslatorKind::all() {
            let d = Translator::of_kind(kind).translate(&program).unwrap();
            assert!(d.hdl_lines > 5, "{}/{:?}", program.name, kind);
            assert!(d.host_lines > 5);
            assert!(d.resources.lut > 0);
            assert!(d.pipeline.peak_teps() > 0.0);
            d.module_graph.validate().unwrap();
        }
    }
}

#[test]
fn paper_table5_code_size_shape() {
    // Table V: 35 (FAgraph) vs 54 (Vivado) vs 128 (Spatial) lines for BFS;
    // we assert the ratios (the paper's point), not the absolutes.
    let p = algorithms::bfs();
    let j = Translator::jgraph().translate(&p).unwrap().hdl_lines as f64;
    let v = Translator::vivado_hls().translate(&p).unwrap().hdl_lines as f64;
    let s = Translator::spatial().translate(&p).unwrap().hdl_lines as f64;
    assert!((1.3..2.2).contains(&(v / j)), "vivado/jgraph = {}", v / j);
    assert!((2.8..4.8).contains(&(s / j)), "spatial/jgraph = {}", s / j);
}

#[test]
fn generated_hdl_mentions_every_pipeline_stage() {
    let d = Translator::jgraph().translate(&algorithms::sssp()).unwrap();
    for needle in ["edge_fetch", "gather", "reduce_unit", "vertex_wr", "mem_ctrl", "pcie_dma"] {
        assert!(d.hdl.contains(needle), "missing {needle} in HDL:\n{}", d.hdl);
    }
}

#[test]
fn module_graph_scales_with_plan() {
    let p = algorithms::wcc();
    let small = Translator::jgraph()
        .with_plan(ParallelismPlan::new(2, 1))
        .translate(&p)
        .unwrap();
    let big = Translator::jgraph()
        .with_plan(ParallelismPlan::new(16, 2))
        .translate(&p)
        .unwrap();
    assert_eq!(small.module_graph.count(HwModule::EdgeFetcher), 2);
    assert_eq!(big.module_graph.count(HwModule::EdgeFetcher), 32);
    assert!(big.resources.lut > small.resources.lut * 4);
    // shared infrastructure does not replicate
    assert_eq!(big.module_graph.count(HwModule::PcieDma), 1);
}

#[test]
fn oversized_plan_exceeds_u200() {
    let p = algorithms::bfs();
    let d = Translator::jgraph()
        .with_plan(ParallelismPlan::new(512, 8))
        .translate(&p)
        .unwrap();
    assert!(!d.fits(&DeviceModel::u200()), "4096 lanes cannot fit");
    // ... but the default plan does
    let d8 = Translator::jgraph().translate(&p).unwrap();
    assert!(d8.fits(&DeviceModel::u200()));
}

#[test]
fn host_code_reflects_program_needs() {
    let bfs = Translator::jgraph().translate(&algorithms::bfs()).unwrap();
    assert!(bfs.host_c.contains("frontier_size == 0"));
    let sssp = Translator::jgraph().translate(&algorithms::sssp()).unwrap();
    assert!(sssp.host_c.contains("JG_REGION_WEIGHTS"));
    assert!(!bfs.host_c.contains("JG_REGION_WEIGHTS"));
}

#[test]
fn compile_time_ordering_matches_fig5() {
    let p = algorithms::bfs();
    let j = Translator::jgraph().translate(&p).unwrap().compile_seconds();
    let v = Translator::vivado_hls().translate(&p).unwrap().compile_seconds();
    let s = Translator::spatial().translate(&p).unwrap().compile_seconds();
    assert!(j < v && j < s, "light-weight flow must compile fastest: {j} {v} {s}");
}

#[test]
fn chisel_stage_only_in_jgraph_flow_and_consistent() {
    // the paper's pipeline: DSL -> Chisel -> Verilog (jgraph flow only)
    for p in algorithms::all() {
        let j = Translator::jgraph().translate(&p).unwrap();
        let chisel = j.chisel.as_ref().expect("jgraph flow emits Chisel");
        assert!(chisel.contains("extends Module"), "{}", p.name);
        // the converted Verilog is the design's HDL
        assert!(j.hdl.contains("module"));
        let v = Translator::vivado_hls().translate(&p).unwrap();
        assert!(v.chisel.is_none(), "baselines have no Chisel stage");
    }
}

#[test]
fn module_library_covers_every_instantiated_kind() {
    use jgraph::translator::modlib;
    for p in algorithms::all() {
        let d = Translator::jgraph().translate(&p).unwrap();
        let lib = modlib::emit_library(&d.module_graph);
        for inst in &d.module_graph.instances {
            if inst.kind == HwModule::HostOnly {
                continue;
            }
            let body = modlib::module_body(inst.kind);
            assert!(
                lib.contains(body.trim_start()),
                "{}: library missing body for {:?}",
                p.name,
                inst.kind
            );
        }
    }
}

#[test]
fn translate_wall_time_is_microseconds_not_seconds() {
    // the "light-weight" claim, measured: translation itself (excluding
    // the modeled synthesis) is interactive-speed
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        Translator::jgraph().translate(&algorithms::bfs()).unwrap();
    }
    let per = t0.elapsed().as_secs_f64() / 100.0;
    assert!(per < 0.01, "translate took {per}s");
}
