//! Integration: full executor flow (software functional path) across
//! algorithms, graphs, preprocessing options, and translator flows.
//!
//! This suite intentionally keeps exercising the deprecated one-shot
//! `Executor` shim — it is the regression net guaranteeing the shim stays
//! equivalent to the `Session` lifecycle (covered by
//! `integration_session.rs`).
#![allow(deprecated)]

use jgraph::dsl::algorithms;
use jgraph::engine::{Executor, ExecutorConfig, FunctionalPath};
use jgraph::graph::generate;
use jgraph::prep::reorder::ReorderStrategy;
use jgraph::translator::{Translator, TranslatorKind};

fn config(name: &str) -> ExecutorConfig {
    ExecutorConfig { use_xla: false, graph_name: name.into(), ..Default::default() }
}

#[test]
fn all_algorithms_run_on_power_law_graph() {
    let g = generate::rmat(10, 20_000, 0.57, 0.19, 0.19, 11);
    for program in algorithms::all() {
        let design = Translator::jgraph().translate(&program).unwrap();
        let mut ex = Executor::new(config("rmat10"));
        let r = ex.run(&program, &design, &g).unwrap();
        assert!(r.supersteps > 0, "{}", program.name);
        assert!(r.simulated_mteps > 0.0);
        assert_eq!(r.functional_path, FunctionalPath::Software);
    }
}

#[test]
fn bfs_correct_against_handrolled_reference() {
    let g = generate::grid2d(20, 20, 3);
    let program = algorithms::bfs();
    let csr = jgraph::graph::csr::Csr::from_edgelist(&g);
    let result = jgraph::engine::gas::run(&program, &csr, 0, |_| {}).unwrap();
    // grid BFS level of (x, y) from (0,0) = x + y (all weights traversed
    // as hops)
    for y in 0..20 {
        for x in 0..20 {
            let v = y * 20 + x;
            assert_eq!(result.values[v] as usize, x + y, "vertex ({x},{y})");
        }
    }
}

#[test]
fn translator_flow_changes_timing_not_values() {
    let g = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 5);
    let program = algorithms::wcc();
    let mut mteps = Vec::new();
    for kind in TranslatorKind::all() {
        let design = Translator::of_kind(kind).translate(&program).unwrap();
        let mut ex = Executor::new(config("rmat9"));
        let r = ex.run(&program, &design, &g).unwrap();
        mteps.push((kind, r.simulated_mteps, r.supersteps));
    }
    // all flows agree on the algorithm (supersteps identical)...
    assert!(mteps.windows(2).all(|w| w[0].2 == w[1].2));
    // ...but not on performance
    let j = mteps.iter().find(|m| m.0 == TranslatorKind::JGraph).unwrap().1;
    let s = mteps.iter().find(|m| m.0 == TranslatorKind::Spatial).unwrap().1;
    assert!(j > 3.0 * s);
}

#[test]
fn reorder_improves_row_start_cycles_on_shuffled_grid() {
    // shuffle a grid; BFS-locality reorder must reduce row-start stalls
    let grid = generate::grid2d(48, 48, 1);
    let mut rng = jgraph::graph::SplitMix64::new(123);
    let mut perm: Vec<u32> = (0..grid.num_vertices as u32).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        perm.swap(i, j);
    }
    let shuffled = grid.permute(&perm);
    let program = algorithms::sssp();
    let design = Translator::jgraph().translate(&program).unwrap();

    let run = |reorder| {
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false,
            reorder,
            graph_name: "grid".into(),
            ..Default::default()
        });
        ex.run(&program, &design, &shuffled).unwrap()
    };
    let base = run(None);
    let reordered = run(Some(ReorderStrategy::BfsLocality));
    assert!(
        reordered.sim.cycles.row_start < base.sim.cycles.row_start,
        "reorder {} !< base {}",
        reordered.sim.cycles.row_start,
        base.sim.cycles.row_start
    );
}

#[test]
fn parallelism_scales_simulated_throughput() {
    let g = generate::rmat(11, 60_000, 0.57, 0.19, 0.19, 9);
    let program = algorithms::pagerank_with(0.85, 1e-4);
    let mut last = 0.0;
    for pipes in [1u32, 4, 16] {
        let design = Translator::jgraph()
            .with_plan(jgraph::sched::ParallelismPlan::new(pipes, 1))
            .translate(&program)
            .unwrap();
        let mut ex = Executor::new(config("rmat11"));
        let r = ex.run(&program, &design, &g).unwrap();
        assert!(
            r.simulated_mteps > last,
            "{} pipes: {} !> {}",
            pipes,
            r.simulated_mteps,
            last
        );
        last = r.simulated_mteps;
    }
}

#[test]
fn headline_shape_bfs_email_vs_slashdot() {
    // the larger graph must amortize launches better (paper: 314 -> 409).
    // The paper's headline models the push schedule (its BFS streams the
    // frontier's out-edges), so the reproduction band pins PushOnly; the
    // direction-optimizing engine traverses far fewer edges per query and
    // is gated separately in benches/engine_mteps.rs.
    use jgraph::engine::{DirectionPolicy, RunOptions, Session, SessionConfig};
    use jgraph::prep::prepared::PrepOptions;
    let program = algorithms::bfs();
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let compiled = session.compile(&program).unwrap();
    let small = generate::email_eu_core_like(42);
    let bound = compiled.load(&small, PrepOptions::named("email")).unwrap();
    let r_small = bound
        .query(&RunOptions::default().with_direction(DirectionPolicy::PushOnly))
        .unwrap();
    assert_eq!(r_small.pull_supersteps, 0, "push-only pin must hold");
    assert!(
        r_small.simulated_mteps > 150.0 && r_small.simulated_mteps < 900.0,
        "email BFS: {} MTEPS out of plausible band",
        r_small.simulated_mteps
    );
}

#[test]
fn graph_store_feeds_the_full_pipeline() {
    // paper §IV-C1: "we can read data from database directly" — store ->
    // FIFO bridge -> translate -> run
    use jgraph::graph::store::GraphStore;
    let g = generate::rmat(8, 2_000, 0.57, 0.19, 0.19, 21);
    let store = GraphStore::from_edgelist(&g, "Account", "TXN");
    let dir = std::env::temp_dir().join("jgraph_store_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let db = dir.join("accounts.db");
    store.save(&db).unwrap();

    let loaded = GraphStore::load(&db).unwrap();
    let el = loaded.to_edgelist(Some("TXN"));
    assert_eq!(el.num_edges(), g.num_edges());
    let program = algorithms::wcc();
    let design = Translator::jgraph().translate(&program).unwrap();
    let mut ex = Executor::new(config("store"));
    let r = ex.run(&program, &design, &el).unwrap();
    assert!(r.supersteps > 0 && r.simulated_mteps > 0.0);
}

#[test]
fn trace_csv_written_and_consistent() {
    let g = generate::rmat(9, 4_000, 0.57, 0.19, 0.19, 33);
    let program = algorithms::bfs();
    let design = Translator::jgraph().translate(&program).unwrap();
    let path = std::env::temp_dir().join("jgraph_e2e_trace.csv");
    let mut ex = Executor::new(ExecutorConfig {
        use_xla: false,
        graph_name: "rmat9".into(),
        trace_path: Some(path.clone()),
        ..Default::default()
    });
    let r = ex.run(&program, &design, &g).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    // header + one row per superstep
    assert_eq!(csv.lines().count() as u32, r.supersteps + 1);
    // edge column sums to the traversed count
    let total: u64 = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').nth(1).unwrap().parse::<u64>().unwrap())
        .sum();
    assert_eq!(total, r.edges_traversed);
}

#[test]
fn extension_algorithms_run_end_to_end() {
    let g = generate::rmat(9, 5_000, 0.57, 0.19, 0.19, 44);
    for program in [algorithms::reachability(), algorithms::max_label()] {
        let design = Translator::jgraph().translate(&program).unwrap();
        let mut ex = Executor::new(config("rmat9"));
        let r = ex.run(&program, &design, &g).unwrap();
        assert!(r.supersteps > 0, "{}", program.name);
        assert_eq!(r.functional_path, FunctionalPath::Software);
    }
}

#[test]
fn run_report_periods_sum_to_rt() {
    let g = generate::erdos_renyi(300, 3_000, 8);
    let program = algorithms::wcc();
    let design = Translator::vivado_hls().translate(&program).unwrap();
    let mut ex = Executor::new(config("er"));
    let r = ex.run(&program, &design, &g).unwrap();
    let sum = r.prep_seconds
        + r.compile_seconds
        + r.deploy_seconds
        + r.sim_exec_seconds
        + r.functional_exec_seconds
        + r.transfer_seconds;
    assert!((r.rt_seconds - sum).abs() < 1e-9);
    assert!(r.deploy_seconds >= jgraph::engine::executor::FLASH_SECONDS);
}
