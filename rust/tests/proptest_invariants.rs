//! Property-based tests on coordinator invariants. The offline build has
//! no proptest crate, so this file carries a small deterministic
//! random-case driver (`cases`) over the crate's own SplitMix64 — same
//! discipline (random structure, invariant assertion, seed reported on
//! failure), fixed seeds for reproducibility.

use jgraph::accel::device::DeviceModel;
use jgraph::accel::simulator::{AccelSimulator, EdgeBatch};
use jgraph::dsl::algorithms;
use jgraph::dsl::program::Direction;
use jgraph::engine::gas;
use jgraph::engine::gas::{DirectionPolicy, EngineGraph};
use jgraph::graph::csr::Csr;
use jgraph::graph::edgelist::EdgeList;
use jgraph::graph::{generate, SplitMix64};
use jgraph::prep::layout::{convert, Layout};
use jgraph::prep::partition::{destination_ranges, partition, PartitionStrategy};
use jgraph::prep::reorder;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::pipeline::schedule;
use jgraph::translator::TranslatorKind;

/// Run `f` over `n` random cases; panic message names the failing seed.
fn cases(n: u64, f: impl Fn(u64, &mut SplitMix64)) {
    for seed in 0..n {
        let mut rng = SplitMix64::new(0xC0FFEE ^ (seed * 7919));
        f(seed, &mut rng);
    }
}

/// Random graph: up to `max_n` vertices, `max_m` edges.
fn random_graph(rng: &mut SplitMix64, max_n: usize, max_m: usize) -> EdgeList {
    let n = 1 + rng.next_below(max_n as u64) as usize;
    let m = rng.next_below(max_m as u64) as usize;
    let mut el = EdgeList::with_vertices(n);
    for _ in 0..m {
        let s = rng.next_below(n as u64) as u32;
        let d = rng.next_below(n as u64) as u32;
        el.push(s, d, rng.next_f32_range(0.1, 9.0));
    }
    el.num_vertices = n;
    el
}

#[test]
fn prop_partition_covers_every_vertex_exactly_once() {
    let strategies = [
        PartitionStrategy::Range,
        PartitionStrategy::Hash,
        PartitionStrategy::DegreeBalanced,
        PartitionStrategy::BfsGrow,
    ];
    cases(30, |seed, rng| {
        let g = random_graph(rng, 300, 2_000);
        let k = 1 + rng.next_below(9) as usize;
        for s in strategies {
            let p = partition(&g, k, s).unwrap();
            assert_eq!(p.assignment.len(), g.num_vertices, "seed {seed} {s:?}");
            assert!(p.assignment.iter().all(|&a| (a as usize) < k), "seed {seed} {s:?}");
            assert_eq!(
                p.part_sizes.iter().sum::<usize>(),
                g.num_vertices,
                "seed {seed} {s:?}"
            );
            assert_eq!(p.part_edges.iter().sum::<usize>(), g.num_edges(), "seed {seed} {s:?}");
        }
    });
}

#[test]
fn prop_layout_conversions_roundtrip() {
    cases(25, |seed, rng| {
        let mut g = random_graph(rng, 120, 800);
        g.dedup(); // adjacency matrix collapses duplicates
        let canon: Vec<(u32, u32)> =
            g.sorted().edges.iter().map(|e| (e.src, e.dst)).collect();
        for layout in [Layout::EdgeList, Layout::Csr, Layout::Csc, Layout::AdjacencyMatrix] {
            let lo = convert(&g, layout).unwrap();
            let rt: Vec<(u32, u32)> =
                lo.to_edgelist().sorted().edges.iter().map(|e| (e.src, e.dst)).collect();
            assert_eq!(rt, canon, "seed {seed} layout {layout:?}");
        }
    });
}

#[test]
fn prop_reorder_is_degree_preserving_permutation() {
    cases(25, |seed, rng| {
        let g = random_graph(rng, 200, 1_500);
        for &s in reorder::all_strategies() {
            let perm = reorder::permutation(&g, s);
            // bijective
            let mut seen = vec![false; perm.len()];
            for &p in &perm {
                assert!(!seen[p as usize], "seed {seed} {s:?}: not injective");
                seen[p as usize] = true;
            }
            // degree multiset preserved
            let (rg, _) = reorder::reorder(&g, s);
            let mut a = g.out_degrees();
            let mut b = rg.out_degrees();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "seed {seed} {s:?}");
        }
    });
}

#[test]
fn prop_bfs_oracle_matches_naive_reference() {
    cases(20, |seed, rng| {
        let g = random_graph(rng, 150, 900);
        let csr = Csr::from_edgelist(&g);
        let got = gas::run(&algorithms::bfs(), &csr, 0, |_| {}).unwrap();
        // naive BFS
        let mut levels = vec![-1i64; g.num_vertices];
        levels[0] = 0;
        let mut q = std::collections::VecDeque::from([0u32]);
        while let Some(u) = q.pop_front() {
            for (_, v, _) in csr.row_edges(u) {
                if levels[v as usize] < 0 {
                    levels[v as usize] = levels[u as usize] + 1;
                    q.push_back(v);
                }
            }
        }
        for v in 0..g.num_vertices {
            assert_eq!(got.values[v] as i64, levels[v], "seed {seed} vertex {v}");
        }
    });
}

#[test]
fn prop_wcc_labels_are_component_minima() {
    cases(15, |seed, rng| {
        let mut g = random_graph(rng, 100, 300);
        g.symmetrize(); // undirected semantics for component comparison
        let csr = Csr::from_edgelist(&g);
        let got = gas::run(&algorithms::wcc(), &csr, 0, |_| {}).unwrap();
        // union-find reference
        let mut parent: Vec<u32> = (0..g.num_vertices as u32).collect();
        fn find(p: &mut Vec<u32>, mut x: u32) -> u32 {
            while p[x as usize] != x {
                p[x as usize] = p[p[x as usize] as usize];
                x = p[x as usize];
            }
            x
        }
        for e in &g.edges {
            let (a, b) = (find(&mut parent, e.src), find(&mut parent, e.dst));
            if a != b {
                parent[a as usize] = b;
            }
        }
        let mut min_of_root = std::collections::HashMap::new();
        for v in 0..g.num_vertices as u32 {
            let r = find(&mut parent, v);
            let e = min_of_root.entry(r).or_insert(v);
            if v < *e {
                *e = v;
            }
        }
        for v in 0..g.num_vertices as u32 {
            let r = find(&mut parent, v);
            assert_eq!(
                got.values[v as usize] as u32, min_of_root[&r],
                "seed {seed} vertex {v}"
            );
        }
    });
}

#[test]
fn prop_scheduler_never_exceeds_device() {
    use jgraph::sched::scheduler::{auto_plan, RuntimeScheduler};
    use jgraph::translator::resource::ResourceEstimate;
    cases(40, |seed, rng| {
        let lane = ResourceEstimate {
            lut: 1_000 + rng.next_below(80_000),
            ff: 1_000 + rng.next_below(120_000),
            bram_kb: rng.next_below(2_000),
            uram: rng.next_below(50),
            dsp: rng.next_below(200),
        };
        let dev = DeviceModel::u200();
        let req = ParallelismPlan::new(
            1 + rng.next_below(128) as u32,
            1 + rng.next_below(8) as u32,
        );
        if let Ok(s) = RuntimeScheduler::admit(req, &lane, &dev, 10) {
            assert!(
                lane.scaled(s.plan.total_lanes()).fits(&dev),
                "seed {seed}: granted plan exceeds device"
            );
            assert!(s.plan.pipelines <= req.pipelines && s.plan.pes <= req.pes);
        }
        let auto = auto_plan(&lane, &dev, 64, 4);
        assert!(lane.scaled(auto.total_lanes()).fits(&dev), "seed {seed}: auto plan");
    });
}

#[test]
fn prop_simulator_cycles_monotone_in_work_and_antitone_in_lanes() {
    cases(20, |seed, rng| {
        let n_dst = 1 + rng.next_below(5_000) as u32;
        let m1 = 1_000 + rng.next_below(20_000) as usize;
        let m2 = m1 + 5_000;
        let dsts1: Vec<u32> = (0..m1).map(|_| rng.next_below(n_dst as u64) as u32).collect();
        let dsts2: Vec<u32> = (0..m2).map(|_| rng.next_below(n_dst as u64) as u32).collect();
        let dev = DeviceModel::u200();
        let mk = |lanes: u32| {
            schedule(TranslatorKind::JGraph, ParallelismPlan::new(lanes, 1), 20, dev.clock_hz)
        };
        let run = |dsts: &[u32], lanes: u32| {
            let mut sim = AccelSimulator::new(DeviceModel::u200(), mk(lanes));
            sim.superstep(&EdgeBatch {
                dsts,
                active_rows: n_dst as u64,
                bytes_per_edge: 8,
                avg_edge_gap: 50.0,
                direction: Direction::Push,
            });
            sim.finish().cycles.total()
        };
        // more edges -> more cycles (same lanes)
        assert!(run(&dsts2, 8) > run(&dsts1, 8), "seed {seed}: monotone in work");
        // more lanes -> no more cycles (same edges)
        assert!(run(&dsts1, 16) <= run(&dsts1, 2), "seed {seed}: antitone in lanes");
    });
}

#[test]
fn prop_custom_apply_expressions_evaluate_consistently() {
    use jgraph::dsl::apply::{ApplyEnv, ApplyExpr, BinOp};
    // random expression trees: eval must be deterministic and finite for
    // finite positive inputs with safe operators
    cases(50, |seed, rng| {
        fn gen(rng: &mut SplitMix64, depth: u32) -> ApplyExpr {
            if depth == 0 || rng.next_below(3) == 0 {
                return match rng.next_below(4) {
                    0 => ApplyExpr::src(),
                    1 => ApplyExpr::weight(),
                    2 => ApplyExpr::iter(),
                    _ => ApplyExpr::constant(1.0 + rng.next_f64() * 4.0),
                };
            }
            let op = match rng.next_below(4) {
                0 => BinOp::Add,
                1 => BinOp::Mul,
                2 => BinOp::Min,
                _ => BinOp::Max,
            };
            ApplyExpr::bin(op, gen(rng, depth - 1), gen(rng, depth - 1))
        }
        let e = gen(rng, 4);
        let env = ApplyEnv {
            src_value: rng.next_f64() * 10.0,
            dst_value: rng.next_f64() * 10.0,
            edge_weight: 0.1 + rng.next_f64() * 5.0,
            iter_count: rng.next_below(50) as f64,
        };
        let a = e.eval(&env);
        let b = e.eval(&env);
        assert_eq!(a, b, "seed {seed}: eval not deterministic");
        assert!(a.is_finite(), "seed {seed}: {} -> {a}", e.render());
        assert!(e.op_count() >= e.depth(), "seed {seed}");
    });
}

#[test]
fn prop_csr_roundtrip_arbitrary_graphs() {
    cases(30, |seed, rng| {
        let g = random_graph(rng, 200, 2_000);
        let csr = Csr::from_edgelist(&g);
        assert_eq!(csr.num_edges(), g.num_edges(), "seed {seed}");
        let rt = csr.to_edgelist();
        let mut a: Vec<_> = g.edges.iter().map(|e| (e.src, e.dst)).collect();
        let mut b: Vec<_> = rt.edges.iter().map(|e| (e.src, e.dst)).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "seed {seed}");
        // edge_row inverse of row ranges
        for e in 0..csr.num_edges().min(50) {
            let row = csr.edge_row(e as u32);
            let (lo, hi) =
                (csr.offsets[row as usize] as usize, csr.offsets[row as usize + 1] as usize);
            assert!((lo..hi).contains(&e), "seed {seed} edge {e}");
        }
    });
}

#[test]
fn prop_multipe_conserves_edges_and_bounds_critical_path() {
    use jgraph::accel::multipe::{InterconnectModel, MultiPeSimulator};
    cases(15, |seed, rng| {
        let g = random_graph(rng, 400, 6_000);
        if g.num_edges() == 0 {
            return;
        }
        let k = 1 + rng.next_below(4) as usize;
        let p = partition(&g, k, PartitionStrategy::Hash).unwrap();
        let pes = k as u32;
        let dev = DeviceModel::u200();
        let spec = schedule(
            TranslatorKind::JGraph,
            ParallelismPlan::new(1 + rng.next_below(8) as u32, pes),
            20,
            dev.clock_hz,
        );
        let pe_of: Vec<u32> = (0..k as u32).collect();
        let mut sim =
            MultiPeSimulator::new(DeviceModel::u200(), spec, InterconnectModel::default());
        let step = sim.superstep(g.edges.iter().map(|e| (e.src, e.dst)), &p, &pe_of);
        // critical path at least the slowest PE and at least the router fill
        let max_pe = *step.pe_cycles.iter().max().unwrap();
        assert!(step.critical_cycles >= max_pe, "seed {seed}");
        assert!(step.critical_cycles >= step.interconnect_cycles, "seed {seed}");
        // crossing messages cannot exceed total edges
        assert!(step.crossing_msgs <= g.num_edges() as u64, "seed {seed}");
        // single PE -> nothing crosses
        if k == 1 {
            assert_eq!(step.crossing_msgs, 0, "seed {seed}");
        }
    });
}

#[test]
fn prop_isa_dynamic_count_consistent_with_oracle_trace() {
    use jgraph::dsl::isa;
    cases(10, |seed, rng| {
        let g = random_graph(rng, 120, 1_000);
        let csr = Csr::from_edgelist(&g);
        let program = algorithms::wcc();
        let isa_prog = isa::compile(&program);
        let mut total_edges = 0u64;
        let mut total_vertices = 0u64;
        let mut steps = 0u64;
        gas::run(&program, &csr, 0, |t| {
            total_edges += t.dsts.len() as u64;
            total_vertices += t.active_rows;
            steps += 1;
        })
        .unwrap();
        let dyn_count = (0..steps).fold(0u64, |acc, _| acc + isa_prog.per_superstep as u64)
            + isa_prog.per_vertex as u64 * total_vertices
            + isa_prog.per_edge as u64 * total_edges;
        // the affine cost model must agree with per-superstep accumulation
        let mut acc = 0u64;
        let per_step_vertices = total_vertices / steps.max(1);
        let _ = per_step_vertices;
        acc += steps * isa_prog.per_superstep as u64;
        acc += isa_prog.per_vertex as u64 * total_vertices;
        acc += isa_prog.per_edge as u64 * total_edges;
        assert_eq!(dyn_count, acc, "seed {seed}");
        assert!(dyn_count > 0, "seed {seed}");
    });
}

/// The PR 5 tentpole pin: direction-optimized execution is **value- and
/// superstep-identical** to the push-only reference — bitwise on the f64
/// values — across random graphs, algorithms, and roots. Both the
/// heuristic (`Adaptive`) and the always-pull stress mode (`ForcePull`,
/// which exercises the pull kernels even on sparse frontiers) are pinned.
#[test]
fn prop_adaptive_execution_identical_to_push_only() {
    // 104 random graphs overall (the acceptance floor is 100), cycling a
    // mix of Active- and All-frontier programs, rooted and not, weighted
    // and not, Min/Max/Sum reductions.
    cases(104, |seed, rng| {
        let g = random_graph(rng, 220, 2_600);
        let csr = Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let out_deg = csr.out_degrees();
        let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let root = rng.next_below(g.num_vertices as u64) as u32;
        let programs = [
            algorithms::bfs(),
            algorithms::sssp(),
            algorithms::wcc(),
            algorithms::spmv(),
            // loose tolerance keeps the 312-run sweep fast; the equality
            // must hold at any tolerance since every iterate is pinned
            algorithms::pagerank()
                .instantiate(&jgraph::dsl::params::ParamSet::new().bind("tolerance", 1e-3))
                .unwrap(),
            algorithms::reachability(),
            algorithms::widest_path(),
        ];
        for program in &programs {
            let push = gas::run(program, &csr, root, |_| {}).unwrap();
            for policy in [DirectionPolicy::Adaptive, DirectionPolicy::ForcePull] {
                let got =
                    gas::run_with_policy(program, &view, root, policy, |_| Ok(())).unwrap();
                assert_eq!(
                    got.supersteps, push.supersteps,
                    "seed {seed} {} {policy:?}: supersteps",
                    program.name
                );
                assert_eq!(
                    got.converged, push.converged,
                    "seed {seed} {} {policy:?}: converged",
                    program.name
                );
                for v in 0..csr.num_vertices() {
                    assert_eq!(
                        got.values[v].to_bits(),
                        push.values[v].to_bits(),
                        "seed {seed} {} {policy:?} vertex {v}: {} vs {}",
                        program.name,
                        got.values[v],
                        push.values[v]
                    );
                }
            }
        }
    });
}

/// Random (frequently ill-formed) [`jgraph::dsl::program::GasProgram`]:
/// independent draws across the shape axes the lint catalog covers, so the
/// sweep hits both accepted programs and every deny family.
fn random_program(rng: &mut SplitMix64) -> jgraph::dsl::program::GasProgram {
    use jgraph::dsl::apply::{ApplyExpr, BinOp};
    use jgraph::dsl::params::{ParamSignature, ParamSpec, Scalar};
    use jgraph::dsl::program::{
        Convergence, EdgeOpKind, FrontierPolicy, GasProgram, InitPolicy, ReduceOp, StateType,
        Writeback,
    };
    let state = if rng.next_below(2) == 0 { StateType::I32 } else { StateType::F32 };
    let reduce = match rng.next_below(3) {
        0 => ReduceOp::Min,
        1 => ReduceOp::Max,
        _ => ReduceOp::Sum,
    };
    let apply = match rng.next_below(5) {
        0 => ApplyExpr::src(),
        1 => ApplyExpr::bin(BinOp::Add, ApplyExpr::src(), ApplyExpr::weight()),
        2 => ApplyExpr::bin(BinOp::Div, ApplyExpr::src(), ApplyExpr::constant(2.0)),
        3 => ApplyExpr::bin(BinOp::Add, ApplyExpr::iter(), ApplyExpr::constant(1.0)),
        _ => ApplyExpr::src().mul(ApplyExpr::param("alpha")),
    };
    let writeback = match rng.next_below(5) {
        0 => Writeback::MinCombine,
        1 => Writeback::MaxCombine,
        2 => Writeback::IfUnvisited,
        3 => Writeback::Overwrite,
        _ => Writeback::DampedSum(match rng.next_below(3) {
            0 => 0.85.into(),
            1 => 1.5.into(), // statically divergent damping
            _ => Scalar::param("damping"),
        }),
    };
    let convergence = match rng.next_below(4) {
        0 => Convergence::EmptyFrontier,
        1 => Convergence::NoChange,
        2 => Convergence::FixedIterations(rng.next_below(3) as u32),
        _ => Convergence::DeltaBelow(1e-4.into()),
    };
    let mut params = ParamSignature::default();
    if rng.next_below(2) == 0 {
        let spec = if rng.next_below(4) == 0 {
            ParamSpec::new("alpha", 2.0).with_range(0.0, 1.0) // default outside range
        } else {
            ParamSpec::new("alpha", 0.5).with_range(0.0, 1.0)
        };
        params.declare(spec);
    }
    if rng.next_below(3) == 0 {
        params.declare(ParamSpec::new("damping", 0.85).with_range(0.0, 0.99));
    }
    if rng.next_below(4) == 0 {
        params.declare(ParamSpec::new("ghost", 1.0)); // unused: warn only
    }
    let depth_limit = if rng.next_below(4) == 0 {
        Some(Scalar::from(rng.next_below(4) as f64)) // 0 can never run
    } else {
        None
    };
    let init = match rng.next_below(3) {
        0 => InitPolicy::Constant(0.0.into()),
        1 => InitPolicy::root_and_default(0.0, f64::INFINITY), // deny under I32
        _ => InitPolicy::VertexId,
    };
    GasProgram {
        name: "prop-case".into(),
        state,
        init,
        apply,
        reduce,
        writeback,
        frontier: if rng.next_below(2) == 0 { FrontierPolicy::Active } else { FrontierPolicy::All },
        direction: Direction::Push,
        convergence,
        uses_weights: rng.next_below(2) == 0,
        kind: if rng.next_below(5) == 0 { Some(EdgeOpKind::Pr) } else { None },
        params,
        depth_limit,
        delta_iteration_bound: None,
        allowed_lints: Vec::new(),
    }
}

/// The analyzer's reduce-algebra table must agree with brute force: every
/// flag it claims holds on all random triples, and every flag it denies
/// has a concrete counterexample in the sample.
#[test]
fn prop_reduce_algebra_facts_match_brute_force() {
    use jgraph::analysis::{Monotonicity, ReduceAlgebra};
    use jgraph::dsl::program::{ReduceOp, StateType};

    // Evaluate the reduce the way the engine's state type does: the F32
    // datapath rounds every combine, I32 sums are exact.
    fn eval(op: ReduceOp, state: StateType, a: f64, b: f64) -> f64 {
        match (op, state) {
            (ReduceOp::Min, _) => a.min(b),
            (ReduceOp::Max, _) => a.max(b),
            (ReduceOp::Sum, StateType::F32) => (a as f32 + b as f32) as f64,
            (ReduceOp::Sum, StateType::I32) => ((a as i64) + (b as i64)) as f64,
        }
    }

    for op in [ReduceOp::Min, ReduceOp::Max, ReduceOp::Sum] {
        for state in [StateType::I32, StateType::F32] {
            let alg = ReduceAlgebra::of(op, state);
            let mut rng = SplitMix64::new(0xA16E ^ ((op as u64) << 8) ^ (state as u64));
            // nonzero magnitudes across six decades: enough dynamic range
            // to trip float rounding, never a ±0.0 bit ambiguity
            let mut draw = |rng: &mut SplitMix64| {
                let sign = if rng.next_below(2) == 0 { 1.0 } else { -1.0 };
                let v = sign * (1.0 + rng.next_f64() * 9.0)
                    * 10f64.powi(rng.next_below(6) as i32);
                match state {
                    StateType::I32 => v.trunc(),
                    StateType::F32 => v,
                }
            };
            let mut idem_break = false;
            let mut assoc_break = false;
            let mut dec_break = false;
            let mut inc_break = false;
            for i in 0..600 {
                let (a, b, c) = (draw(&mut rng), draw(&mut rng), draw(&mut rng));
                let ab = eval(op, state, a, b);
                // every operator is claimed commutative: bit-exact both ways
                assert_eq!(
                    ab.to_bits(),
                    eval(op, state, b, a).to_bits(),
                    "{op:?}/{state:?} case {i}: not commutative"
                );
                let aa = eval(op, state, a, a);
                if alg.idempotent {
                    assert_eq!(aa.to_bits(), a.to_bits(), "{op:?}/{state:?} case {i}");
                } else if aa.to_bits() != a.to_bits() {
                    idem_break = true;
                }
                let l = eval(op, state, ab, c);
                let r = eval(op, state, a, eval(op, state, b, c));
                if alg.associative {
                    assert_eq!(
                        l.to_bits(),
                        r.to_bits(),
                        "{op:?}/{state:?} case {i}: ({a}, {b}, {c}) regroups"
                    );
                } else if l.to_bits() != r.to_bits() {
                    assoc_break = true;
                }
                match alg.monotonicity {
                    Monotonicity::Decreasing => {
                        assert!(ab <= a.min(b), "{op:?}/{state:?} case {i}")
                    }
                    Monotonicity::Increasing => {
                        assert!(ab >= a.max(b), "{op:?}/{state:?} case {i}")
                    }
                    Monotonicity::NonMonotone => {
                        if ab > a.min(b) {
                            dec_break = true;
                        }
                        if ab < a.max(b) {
                            inc_break = true;
                        }
                    }
                }
            }
            if !alg.idempotent {
                assert!(idem_break, "{op:?}/{state:?}: no idempotence counterexample");
            }
            if !alg.associative {
                assert!(assoc_break, "{op:?}/{state:?}: no associativity counterexample");
            }
            if alg.monotonicity == Monotonicity::NonMonotone {
                assert!(dec_break && inc_break, "{op:?}/{state:?}: monotone after all?");
            }
        }
    }
}

/// `validate::check` and the lint engine are the same judgment: a random
/// program is rejected iff it has a deny-level diagnostic, and the
/// rejection message carries the stable `[JGxxx]` code.
#[test]
fn prop_check_rejects_exactly_the_deny_linted_programs() {
    use jgraph::analysis::lint::first_deny;
    let (mut accepted, mut rejected) = (0u32, 0u32);
    for seed in 0..400u64 {
        let mut rng = SplitMix64::new(0xBADC0DE ^ (seed * 2654435761));
        let p = random_program(&mut rng);
        let deny = first_deny(&p);
        match jgraph::dsl::validate::check(&p) {
            Ok(()) => {
                assert!(deny.is_none(), "seed {seed}: check passed but lint denies {deny:?}");
                accepted += 1;
            }
            Err(e) => {
                let d = deny
                    .unwrap_or_else(|| panic!("seed {seed}: rejected without a deny lint: {e}"));
                assert_eq!(e.to_string(), d.message, "seed {seed}");
                assert!(
                    e.to_string().ends_with(&format!("[{}]", d.code.code())),
                    "seed {seed}: rejection must end with the stable code: {e}"
                );
                rejected += 1;
            }
        }
    }
    // the generator must exercise both sides or the property is vacuous
    assert!(accepted >= 25, "only {accepted}/400 accepted");
    assert!(rejected >= 25, "only {rejected}/400 rejected");
}

/// The derived `pull_early_exit` fact is exactly the engine's legacy
/// shape condition (constant-per-superstep message, visited-gate
/// writeback, non-Sum reduce) on arbitrary programs.
#[test]
fn prop_pull_early_exit_fact_equals_legacy_shape_condition() {
    use jgraph::analysis::analyze;
    use jgraph::dsl::apply::CompiledApply;
    use jgraph::dsl::program::{ReduceOp, Writeback};
    let mut saw_exit = false;
    for seed in 0..600u64 {
        let mut rng = SplitMix64::new(0xEA51E ^ (seed * 40503));
        let p = random_program(&mut rng);
        let legacy = CompiledApply::compile(&p.apply) == CompiledApply::ConstPerIter
            && p.writeback == Writeback::IfUnvisited
            && p.reduce != ReduceOp::Sum;
        assert_eq!(analyze(&p).pull_early_exit, legacy, "seed {seed}: {p:?}");
        saw_exit |= legacy;
    }
    assert!(saw_exit, "generator never produced an early-exit-legal shape");
}

/// The PR 7 tentpole pin: sharded execution — per-partition CSR/CSC
/// shards, per-shard push/pull decisions, threaded shard workers,
/// deterministic boundary merge — is **bitwise identical** to the
/// monolithic interpreter in values and supersteps, across random
/// graphs, every partition strategy, shard counts {1,2,4,7}, and every
/// direction policy. Destination ownership is what makes this hold even
/// for the order-sensitive float Sum programs.
#[test]
fn prop_sharded_execution_identical_to_monolithic() {
    use jgraph::engine::run_sharded;
    use jgraph::prep::shard::ShardedGraph;
    let strategies = [
        PartitionStrategy::Range,
        PartitionStrategy::Hash,
        PartitionStrategy::DegreeBalanced,
        PartitionStrategy::BfsGrow,
    ];
    cases(8, |seed, rng| {
        let g = random_graph(rng, 150, 1_200);
        let csr = Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let out_deg = csr.out_degrees();
        let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let root = rng.next_below(g.num_vertices as u64) as u32;
        // one worker count per case, cycling 1..=4 (1 = the inline serial
        // path, >1 = the std::thread::scope path)
        let workers = 1 + (seed as usize % 4);
        let programs = [
            algorithms::bfs(),
            algorithms::pagerank()
                .instantiate(&jgraph::dsl::params::ParamSet::new().bind("tolerance", 1e-3))
                .unwrap(),
        ];
        let monos: Vec<_> = programs
            .iter()
            .map(|p| gas::run(p, &csr, root, |_| {}).unwrap())
            .collect();
        for strategy in strategies {
            for k in [1usize, 2, 4, 7] {
                let p = partition(&g, k, strategy).unwrap();
                let sg = ShardedGraph::build(&csr, &csc, &p);
                for (program, mono) in programs.iter().zip(&monos) {
                    for policy in [
                        DirectionPolicy::Adaptive,
                        DirectionPolicy::PushOnly,
                        DirectionPolicy::ForcePull,
                    ] {
                        let got =
                            run_sharded(program, &view, &sg, root, policy, workers, |_| Ok(()))
                                .unwrap();
                        assert_eq!(
                            got.result.supersteps, mono.supersteps,
                            "seed {seed} {} {strategy:?} k={k} {policy:?}: supersteps",
                            program.name
                        );
                        assert_eq!(
                            got.result.converged, mono.converged,
                            "seed {seed} {} {strategy:?} k={k} {policy:?}: converged",
                            program.name
                        );
                        for v in 0..csr.num_vertices() {
                            assert_eq!(
                                got.result.values[v].to_bits(),
                                mono.values[v].to_bits(),
                                "seed {seed} {} {strategy:?} k={k} {policy:?} vertex {v}: \
                                 {} vs {}",
                                program.name,
                                got.result.values[v],
                                mono.values[v]
                            );
                        }
                    }
                }
            }
        }
    });
}

/// Sharded edge cases: empty shards (more parts than vertices), an
/// all-cut partitioning (hash split of a chain — every edge crosses),
/// and one vertex per shard — all bit-identical to monolithic.
#[test]
fn prop_sharded_edge_cases_empty_allcut_and_singleton_shards() {
    use jgraph::engine::run_sharded;
    use jgraph::prep::shard::ShardedGraph;
    let check = |g: &EdgeList, k: usize, strategy: PartitionStrategy| {
        let csr = Csr::from_edgelist(g);
        let csc = csr.transpose();
        let out_deg = csr.out_degrees();
        let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let p = partition(g, k, strategy).unwrap();
        let sg = ShardedGraph::build(&csr, &csc, &p);
        for program in [algorithms::bfs(), algorithms::sssp()] {
            let mono = gas::run(&program, &csr, 0, |_| {}).unwrap();
            let got = run_sharded(&program, &view, &sg, 0, DirectionPolicy::Adaptive, 4, |_| {
                Ok(())
            })
            .unwrap();
            assert_eq!(
                got.result.supersteps, mono.supersteps,
                "{} k={k} {strategy:?}",
                program.name
            );
            for v in 0..csr.num_vertices() {
                assert_eq!(
                    got.result.values[v].to_bits(),
                    mono.values[v].to_bits(),
                    "{} k={k} {strategy:?} vertex {v}",
                    program.name
                );
            }
        }
    };
    // empty shards: 7 parts over 3 vertices
    check(&generate::chain(3), 7, PartitionStrategy::Range);
    // all-cut: hash split of a chain alternates parts along every edge
    let chain = generate::chain(12);
    let p = partition(&chain, 2, PartitionStrategy::Hash).unwrap();
    assert_eq!(p.cut_edges, chain.num_edges(), "hash chain: every edge must cross");
    check(&chain, 2, PartitionStrategy::Hash);
    // one vertex per shard
    check(&generate::chain(5), 5, PartitionStrategy::Range);
}

/// The PR 8 tentpole pin: the *auto* layout — edge-prefix-sum
/// destination ranges built for an un-partitioned binding — run through
/// the sharded engine is **bitwise identical** to the monolithic
/// interpreter across random graphs, {BFS, parameterized PageRank}
/// (float Sum included), shard counts {1,2,4,7}, and worker counts
/// including 1 (the single-core budget: every shard runs serially
/// inline). Destination ownership is what makes the float Sum hold.
#[test]
fn prop_auto_sharded_execution_identical_to_monolithic() {
    use jgraph::engine::run_sharded;
    use jgraph::prep::shard::ShardedGraph;
    cases(10, |seed, rng| {
        let g = random_graph(rng, 150, 1_200);
        let csr = Csr::from_edgelist(&g);
        let csc = csr.transpose();
        let out_deg = csr.out_degrees();
        let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let root = rng.next_below(g.num_vertices as u64) as u32;
        // one worker count per case, cycling 1..=4 (1 = the serial inline
        // path a single-core WorkerBudget degrades to, >1 = threaded)
        let workers = 1 + (seed as usize % 4);
        let programs = [
            algorithms::bfs(),
            algorithms::pagerank()
                .instantiate(&jgraph::dsl::params::ParamSet::new().bind("tolerance", 1e-3))
                .unwrap(),
        ];
        let monos: Vec<_> =
            programs.iter().map(|p| gas::run(p, &csr, root, |_| {}).unwrap()).collect();
        for k in [1usize, 2, 4, 7] {
            let p = destination_ranges(&csr, &csc, k);
            // the auto layout owns destinations in contiguous ranges:
            // that is the invariant the exchange-free merge relies on
            let mut prev = 0u32;
            for &a in &p.assignment {
                assert!(a >= prev, "seed {seed} k={k}: ranges must be contiguous");
                prev = a;
            }
            let sg = ShardedGraph::build(&csr, &csc, &p);
            for (program, mono) in programs.iter().zip(&monos) {
                for policy in [
                    DirectionPolicy::Adaptive,
                    DirectionPolicy::PushOnly,
                    DirectionPolicy::ForcePull,
                ] {
                    let got = run_sharded(program, &view, &sg, root, policy, workers, |_| Ok(()))
                        .unwrap();
                    assert_eq!(
                        got.result.supersteps, mono.supersteps,
                        "seed {seed} {} k={k} {policy:?}: supersteps",
                        program.name
                    );
                    assert_eq!(
                        got.result.converged, mono.converged,
                        "seed {seed} {} k={k} {policy:?}: converged",
                        program.name
                    );
                    for v in 0..csr.num_vertices() {
                        assert_eq!(
                            got.result.values[v].to_bits(),
                            mono.values[v].to_bits(),
                            "seed {seed} {} k={k} {policy:?} vertex {v}: {} vs {}",
                            program.name,
                            got.result.values[v],
                            mono.values[v]
                        );
                    }
                }
            }
        }
    });
}

/// Auto-shard edge cases: fewer vertices than shards (trailing ranges
/// empty), an edge-free graph, and a single-worker budget — all
/// bit-identical to monolithic, and the end-to-end `PreparedGraph` gate
/// behaves: tiny graphs never auto-shard on their own, a pin clamps to
/// the vertex count, and user partitionings suppress the auto layout.
#[test]
fn prop_auto_shard_edge_cases_and_prepared_gating() {
    use jgraph::engine::run_sharded;
    use jgraph::prep::prepared::{PrepOptions, PreparedGraph};
    use jgraph::prep::shard::ShardedGraph;
    let check = |g: &EdgeList, k: usize, workers: usize| {
        let csr = Csr::from_edgelist(g);
        let csc = csr.transpose();
        let out_deg = csr.out_degrees();
        let view = EngineGraph::with_csc(&csr, &csc, Some(&out_deg));
        let p = destination_ranges(&csr, &csc, k);
        let sg = ShardedGraph::build(&csr, &csc, &p);
        for program in [algorithms::bfs(), algorithms::sssp()] {
            let mono = gas::run(&program, &csr, 0, |_| {}).unwrap();
            let got =
                run_sharded(&program, &view, &sg, 0, DirectionPolicy::Adaptive, workers, |_| {
                    Ok(())
                })
                .unwrap();
            assert_eq!(
                got.result.supersteps, mono.supersteps,
                "{} k={k} w={workers}",
                program.name
            );
            for v in 0..csr.num_vertices() {
                assert_eq!(
                    got.result.values[v].to_bits(),
                    mono.values[v].to_bits(),
                    "{} k={k} w={workers} vertex {v}",
                    program.name
                );
            }
        }
    };
    // fewer vertices than shards: 7 ranges over 3 vertices
    check(&generate::chain(3), 7, 4);
    // edge-free graph: every range empty of work
    check(&EdgeList::with_vertices(5), 4, 4);
    // single-worker budget: the threaded dispatch degrades to serial
    check(&generate::chain(12), 4, 1);

    // end-to-end gating on PreparedGraph: a tiny graph stays monolithic
    // unless pinned, and the pin clamps to the vertex count
    let tiny = generate::chain(3);
    let auto = PreparedGraph::prepare(&tiny, &PrepOptions::named("tiny")).unwrap();
    assert!(auto.auto_sharded().is_none(), "3-vertex chain is far below the auto gate");
    let pinned =
        PreparedGraph::prepare(&tiny, &PrepOptions::named("tiny").with_auto_shards(7)).unwrap();
    let sg = pinned.auto_sharded().expect("pinned auto-shards must engage");
    assert!(sg.num_shards >= 2 && sg.num_shards <= 3, "pin clamps to the vertex count");
    // a user partitioning always wins over the auto layout
    let parted = PreparedGraph::prepare(
        &tiny,
        &PrepOptions::named("tiny").with_partition(2, PartitionStrategy::Hash).with_auto_shards(4),
    )
    .unwrap();
    assert!(parted.auto_sharded().is_none(), "user partitioning suppresses auto-sharding");
}

#[test]
fn prop_generators_always_valid() {
    cases(15, |seed, rng| {
        let scale = 4 + rng.next_below(6) as u32;
        let m = rng.next_below(5_000) as usize;
        let g = generate::rmat(scale, m, 0.57, 0.19, 0.19, seed);
        assert!(g.is_valid(), "rmat seed {seed}");
        assert_eq!(g.num_edges(), m);
        let g = generate::erdos_renyi(1 + rng.next_below(500) as usize, m, seed);
        assert!(g.is_valid(), "er seed {seed}");
    });
}
