//! Integration for **first-class runtime parameters**: one
//! `Session::compile` of the parameterized PageRank serves queries at
//! many damping/tolerance settings with zero recompiles — the emitted
//! HDL and the sanitized kernel name are identical across settings, the
//! per-setting results match an independent software oracle, and binding
//! failures are typed errors that name the offending parameter.

use jgraph::dsl::algorithms;
use jgraph::dsl::apply::ApplyExpr;
use jgraph::dsl::builder::GasProgramBuilder;
use jgraph::dsl::params::{ParamError, ParamSet, ParamSpec};
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::csr::Csr;
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::translator::{codegen_hdl, Translator};

fn software_session() -> Session {
    Session::new(SessionConfig { use_xla: false, ..Default::default() })
}

use jgraph::engine::gas::reference_pagerank;

/// The acceptance scenario: compile once, query at three distinct
/// damping/tolerance settings, verify each against the oracle.
#[test]
fn one_compile_serves_three_parameter_settings_correctly() {
    let g = generate::rmat(9, 6_000, 0.57, 0.19, 0.19, 42);
    let csr = Csr::from_edgelist(&g);

    let session = software_session();
    // exactly ONE compile for the whole parameter family
    let pipeline = session.compile(&algorithms::pagerank()).unwrap();
    let bound = pipeline.load(&g, PrepOptions::named("rmat9")).unwrap();

    // stiffness budget: delta decays ~damping^k and the engine bounds PR
    // at 200 supersteps, so every setting must satisfy
    // log(tolerance)/log(damping) << 200
    let settings = [(0.5, 1e-8), (0.85, 1e-8), (0.9, 1e-5)];
    let mut supersteps = Vec::new();
    for (damping, tolerance) in settings {
        let set = ParamSet::new().bind("damping", damping).bind("tolerance", tolerance);
        let r = bound
            .query(&RunOptions { params: set.clone(), ..RunOptions::default() })
            .unwrap();
        // the report records the effective binding
        assert_eq!(
            r.bound_params,
            vec![("damping".to_string(), damping), ("tolerance".to_string(), tolerance)]
        );
        // correctness per setting: the query's functional path runs the
        // instantiated program through the GAS oracle — replay it and
        // check its values against the independent reference above
        let instantiated = pipeline.program().instantiate(&set).unwrap();
        let oracle = jgraph::engine::gas::run(&instantiated, &csr, 0, |_| {}).unwrap();
        assert_eq!(oracle.supersteps, r.supersteps, "report mirrors the functional run");
        let expected = reference_pagerank(&csr, damping, oracle.supersteps);
        for (i, (a, b)) in oracle.values.iter().zip(&expected).enumerate() {
            assert!((a - b).abs() < 1e-9, "damping {damping} vertex {i}: {a} vs {b}");
        }
        supersteps.push(r.supersteps);
    }
    assert_eq!(bound.queries_run(), settings.len() as u64, "zero recompiles, one binding");
    // distinct settings genuinely change the computation
    assert!(supersteps[0] < supersteps[2], "stiffer damping needs more iterations");
}

/// The translator-side guarantee: the design is parameter-independent —
/// same HDL bytes, same host driver, same sanitized kernel name (the AOT
/// artifact / xclbin cache key) across a damping sweep.
#[test]
#[allow(deprecated)]
fn emitted_design_and_kernel_name_identical_across_damping_sweep() {
    let reference = Translator::jgraph().translate(&algorithms::pagerank()).unwrap();
    for damping in [0.05, 0.25, 0.5, 0.75, 0.95] {
        let p = algorithms::pagerank_with(damping, 1e-7);
        let d = Translator::jgraph().translate(&p).unwrap();
        assert_eq!(d.hdl, reference.hdl, "damping {damping}: HDL must not change");
        assert_eq!(d.host_c, reference.host_c, "damping {damping}: host C must not change");
        assert_eq!(d.chisel, reference.chisel, "damping {damping}: Chisel must not change");
        assert_eq!(
            codegen_hdl::sanitize(&d.program_name),
            "pagerank",
            "kernel name is the artifact cache key: it must be value-independent"
        );
    }
}

/// An unbound **required** parameter (declared without a default) is a
/// typed error naming the missing parameter — both at the typed pre-flight
/// API and through the query path.
#[test]
fn unbound_required_param_is_a_typed_error_naming_it() {
    let session = software_session();
    // min(src, ceiling): a capacity-style sweep with a required ceiling
    let program = GasProgramBuilder::new("capped-label")
        .apply(ApplyExpr::bin(
            jgraph::dsl::apply::BinOp::Min,
            ApplyExpr::src(),
            ApplyExpr::param("ceiling"),
        ))
        .reduce(jgraph::dsl::program::ReduceOp::Min)
        .param(ParamSpec::required("ceiling"))
        .build()
        .unwrap();
    let pipeline = session.compile(&program).unwrap();

    // typed pre-flight: ParamError::Unbound carries the name
    let err = pipeline.resolve_params(&ParamSet::new()).unwrap_err();
    assert_eq!(err, ParamError::Unbound { name: "ceiling".into() });

    // the run path refuses too, naming the parameter in its message
    let g = generate::erdos_renyi(50, 300, 3);
    let bound = pipeline.load(&g, PrepOptions::named("er")).unwrap();
    let err = bound.query(&RunOptions::default()).unwrap_err();
    assert!(err.to_string().contains("\"ceiling\""), "{err}");
    assert!(err.to_string().contains("unbound"), "{err}");

    // binding it makes the very same binding serve the query
    let r = bound.query(&RunOptions::default().bind("ceiling", 3.0)).unwrap();
    assert!(r.supersteps > 0);
}

/// Unknown and out-of-range bindings are typed at the pre-flight API.
#[test]
fn unknown_and_out_of_range_bindings_are_typed() {
    let session = software_session();
    let pipeline = session.compile(&algorithms::pagerank()).unwrap();
    match pipeline.resolve_params(&ParamSet::new().bind("dampng", 0.9)).unwrap_err() {
        ParamError::Unknown { name, declared } => {
            assert_eq!(name, "dampng");
            assert_eq!(declared, vec!["damping".to_string(), "tolerance".to_string()]);
        }
        other => panic!("expected Unknown, got {other:?}"),
    }
    match pipeline.resolve_params(&ParamSet::new().bind("damping", -0.2)).unwrap_err() {
        ParamError::OutOfRange { name, value, min, max } => {
            assert_eq!((name.as_str(), value, min, max), ("damping", -0.2, 0.0, 1.0));
        }
        other => panic!("expected OutOfRange, got {other:?}"),
    }
}

/// The xclbin the simulated shell is configured with carries the
/// parameter-independent name: two bindings from differently pre-bound
/// constructors hit the same deployment artifact.
#[test]
#[allow(deprecated)]
fn xclbin_and_artifact_key_hit_cache_across_parameter_values() {
    let session = software_session();
    let a = session.compile(&algorithms::pagerank_with(0.85, 1e-6)).unwrap();
    let b = session.compile(&algorithms::pagerank_with(0.95, 1e-9)).unwrap();
    assert_eq!(a.design().program_name, b.design().program_name);
    assert_eq!(a.design().hdl, b.design().hdl);
    assert_eq!(a.program().kind, b.program().kind, "same AOT artifact family");
    // the sanitized name that keys artifact lookup and shell configure
    assert_eq!(codegen_hdl::sanitize(&a.design().program_name), "pagerank");
}

/// Depth-bounded BFS through the full lifecycle: the same compiled design
/// truncates at the bound horizon and the report reflects it.
#[test]
fn bfs_max_depth_binds_through_the_lifecycle() {
    let session = software_session();
    let pipeline = session.compile(&algorithms::bfs()).unwrap();
    let g = generate::chain(40);
    let bound = pipeline.load(&g, PrepOptions::named("chain")).unwrap();
    let full = bound.query(&RunOptions::from_root(0)).unwrap();
    let capped = bound.query(&RunOptions::from_root(0).bind("max_depth", 5.0)).unwrap();
    assert!(capped.supersteps < full.supersteps);
    assert_eq!(capped.supersteps, 5);
    assert_eq!(capped.bound_params, vec![("max_depth".to_string(), 5.0)]);
}
