//! Failure injection: malformed programs, over-capacity designs, corrupt
//! artifacts, device faults, and bad input files must fail loudly with
//! actionable errors — never wrong numbers.
#![allow(deprecated)] // the over-capacity path is exercised through the legacy shim too

use jgraph::comm::CommManager;
use jgraph::dsl::algorithms;
use jgraph::dsl::apply::ApplyExpr;
use jgraph::dsl::builder::GasProgramBuilder;
use jgraph::dsl::program::{ReduceOp, StateType, Writeback};
use jgraph::engine::{Executor, ExecutorConfig};
use jgraph::graph::{csr::Csr, generate, io};
use jgraph::runtime::Manifest;
use jgraph::sched::ParallelismPlan;
use jgraph::translator::Translator;

#[test]
fn malformed_program_rejected_with_interface_level_error() {
    let err = GasProgramBuilder::new("accumulating-bfs")
        .state(StateType::I32)
        .apply(ApplyExpr::src())
        .reduce(ReduceOp::Sum)
        .writeback(Writeback::IfUnvisited)
        .build()
        .unwrap_err()
        .to_string();
    // the error names DSL concepts, not translator internals
    assert!(err.contains("Reduce(Sum)"), "{err}");
    assert!(err.contains("Writeback"), "{err}");
}

#[test]
fn over_capacity_design_refused_by_executor() {
    let program = algorithms::bfs();
    let design = Translator::jgraph()
        .with_plan(ParallelismPlan::new(512, 8)) // 4096 lanes: cannot fit
        .translate(&program)
        .unwrap();
    let g = generate::chain(50);
    let mut ex = Executor::new(ExecutorConfig {
        use_xla: false,
        graph_name: "chain".into(),
        ..Default::default()
    });
    let err = ex.run(&program, &design, &g).unwrap_err().to_string();
    assert!(err.contains("does not fit"), "{err}");
}

#[test]
fn unconfigured_device_rejects_dma() {
    let g = Csr::from_edgelist(&generate::chain(5));
    let cm = CommManager::new();
    let err = cm.transport_graph(&g).unwrap_err().to_string();
    assert!(err.contains("not configured"), "{err}");
}

#[test]
fn device_error_state_blocks_until_reset() {
    let mut cm = CommManager::new();
    cm.shell.configure("x.xclbin", 8, 1).unwrap();
    cm.shell.inject_error();
    let g = Csr::from_edgelist(&generate::chain(5));
    assert!(cm.transport_graph(&g).is_err());
    cm.shell.reset();
    cm.shell.configure("x.xclbin", 8, 1).unwrap();
    assert!(cm.transport_graph(&g).is_ok());
}

#[test]
fn corrupt_graph_files_fail_loudly() {
    let dir = std::env::temp_dir().join("jgraph_failure_injection");
    std::fs::create_dir_all(&dir).unwrap();

    // truncated binary
    let p = dir.join("trunc.bin");
    std::fs::write(&p, b"JGRAPH01\x05\x00\x00\x00\x00\x00\x00\x00\xff\x00").unwrap();
    assert!(io::read_binary(&p).is_err());

    // garbage text
    let p2 = dir.join("garbage.txt");
    std::fs::write(&p2, "0 not_a_vertex\n").unwrap();
    assert!(io::read_snap_text(&p2).is_err());

    // out-of-range endpoint in binary
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"JGRAPH01");
    bytes.extend_from_slice(&2u64.to_le_bytes()); // n = 2
    bytes.extend_from_slice(&1u64.to_le_bytes()); // m = 1
    bytes.extend_from_slice(&9u32.to_le_bytes()); // src = 9 (out of range)
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&1.0f32.to_le_bytes());
    let p3 = dir.join("oob.bin");
    std::fs::write(&p3, &bytes).unwrap();
    let err = io::read_binary(&p3).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
}

#[test]
fn corrupt_manifest_rejected() {
    assert!(Manifest::parse("").is_err());
    assert!(Manifest::parse("not\ta\tmanifest\n").is_err());
    // wrong dtype in tensor spec
    assert!(Manifest::parse("bfs\tt\t1\t1\t1\t1\tf.hlo\tsha\tx:u64:5\t\n").is_err());
    // non-numeric n
    assert!(Manifest::parse("bfs\tt\tNaN\t1\t1\t1\tf.hlo\tsha\t\t\n").is_err());
}

#[test]
fn truncated_hlo_artifact_fails_at_load_not_execute() {
    // requires the PJRT runtime; write a corrupt artifact + manifest into
    // a temp dir and point a registry at it
    let dir = std::env::temp_dir().join("jgraph_bad_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "HloModule truncated garbage (((").unwrap();
    std::fs::write(
        dir.join("manifest.tsv"),
        "bfs\ttiny\t256\t4096\t4096\t1\tbad.hlo.txt\tdeadbeef\tlevels:i32:256\tnew_levels:i32:256\n",
    )
    .unwrap();
    let reg = jgraph::runtime::KernelRegistry::open(dir).unwrap();
    assert!(
        reg.for_bucket("bfs", "tiny").map(|_| ()).is_err(),
        "corrupt HLO text must fail to parse/compile"
    );
}

#[test]
fn missing_artifact_bucket_names_alternatives() {
    let reg = match jgraph::runtime::KernelRegistry::open_default() {
        Ok(r) => r,
        Err(_) => return, // artifacts not built in this checkout
    };
    // graph too large for any bucket
    let err = reg.for_graph("bfs", 10_000_000, 100_000_000).unwrap_err().to_string();
    assert!(err.contains("no artifact bucket"), "{err}");
    assert!(err.contains("large"), "should list available buckets: {err}");
}

#[test]
fn scheduler_iteration_cap_reported() {
    use jgraph::accel::device::DeviceModel;
    use jgraph::sched::scheduler::RuntimeScheduler;
    use jgraph::translator::resource::ResourceEstimate;
    let mut s = RuntimeScheduler::admit(
        ParallelismPlan::new(1, 1),
        &ResourceEstimate { lut: 10, ff: 10, bram_kb: 1, uram: 0, dsp: 0 },
        &DeviceModel::u200(),
        1,
    )
    .unwrap();
    s.begin_superstep(5).unwrap();
    s.end_superstep(5);
    let err = s.begin_superstep(5).unwrap_err().to_string();
    assert!(err.contains("iteration cap"), "{err}");
}
