//! Compile-once / run-many: the paper's core economics ("tens of seconds
//! to generate, then many fast traversals") demonstrated as wall-clock.
//!
//! Query 1 is **cold**: it pays the whole lifecycle — the FIFO/Read stage
//! (here: generating the synthetic graph), `Session::compile` (translate,
//! schedule, modeled synthesis + flash, XLA artifact lookup), and
//! `CompiledPipeline::load` (Reorder + Partition + Layout + transport) —
//! before running. Queries 2..N are **warm**: they reuse the bound
//! pipeline and skip translate/prep/flash entirely, paying only the
//! superstep loop.
//!
//! ```sh
//! cargo run --release --example multi_query
//! ```

use std::time::Instant;

use jgraph::prelude::*;
use jgraph::prep::partition::PartitionStrategy;
use jgraph::prep::reorder::ReorderStrategy;

const NUM_QUERIES: usize = 16;

fn main() -> anyhow::Result<()> {
    // ------------------------------------------------------------------
    // query 1 (cold): read + compile + load + run
    // ------------------------------------------------------------------
    let t_cold = Instant::now();

    // the FIFO/Read stage (paper §IV-C1): the dataset is produced and
    // ingested from disk in SNAP text format (how the paper's evaluation
    // graphs actually ship) — a power-law graph, ~500k follows
    let spool = std::env::temp_dir().join("jgraph_multi_query.txt");
    let produced = jgraph::graph::generate::rmat(14, 500_000, 0.57, 0.19, 0.19, 2026);
    jgraph::graph::io::write_snap_text(&produced, &spool)?;
    let graph = jgraph::graph::io::load(&spool)?;

    let session = Session::new(SessionConfig::default());
    let pipeline = session.compile(&algorithms::bfs())?;

    let mut bound = pipeline.load(
        &graph,
        PrepOptions::named("rmat-14")
            .with_reorder(ReorderStrategy::BfsLocality)
            .with_partition(4, PartitionStrategy::BfsGrow),
    )?;

    let first = bound.run(&RunOptions::from_root(0))?;
    let cold_seconds = t_cold.elapsed().as_secs_f64();
    println!(
        "query  1 (cold): read+compile+load+run in {:.1} ms wall \
         ({} supersteps, {:.1} MTEPS simulated)",
        cold_seconds * 1e3,
        first.supersteps,
        first.simulated_mteps
    );

    // ------------------------------------------------------------------
    // queries 2..=N (warm): bound.run only — translate/prep/flash skipped
    // ------------------------------------------------------------------
    // roots with out-edges in the prepared (reordered) id space, so every
    // query does real traversal work
    let csr = &bound.graph().csr;
    let n = csr.num_vertices() as u32;
    let queries: Vec<RunOptions> = (1..NUM_QUERIES)
        .map(|i| {
            let mut v = (i as u32 * 104_729) % n;
            while csr.degree(v) == 0 {
                v = (v + 1) % n;
            }
            RunOptions::from_root(v)
        })
        .collect();

    let t_warm = Instant::now();
    let reports = bound.run_batch(&queries)?;
    let warm_seconds = t_warm.elapsed().as_secs_f64();
    let warm_avg = warm_seconds / reports.len() as f64;

    for (i, r) in reports.iter().enumerate() {
        println!(
            "query {:>2} (warm): root {:>6} -> {} supersteps, {:>7} edges, {:.1} MTEPS",
            i + 2,
            queries[i].root,
            r.supersteps,
            r.edges_traversed,
            r.simulated_mteps
        );
    }

    // ------------------------------------------------------------------
    // the amortization claim, in both wall-clock and modeled seconds
    // ------------------------------------------------------------------
    let speedup = cold_seconds / warm_avg;
    println!(
        "\nwall-clock:  cold query {:.1} ms, warm query avg {:.2} ms -> {:.1}x \
         lower per-query cost once bound",
        cold_seconds * 1e3,
        warm_avg * 1e3,
        speedup
    );
    println!(
        "modeled:     one-time setup {:.1}s (prep {:.2} + compile {:.1} + flash/deploy {:.2}) \
         vs {:.1} us simulated exec per query",
        first.setup_seconds,
        first.prep_seconds,
        first.compile_seconds,
        first.deploy_seconds,
        first.sim_exec_seconds * 1e6
    );
    let amortized: f64 =
        reports.iter().map(|r| r.simulated_mteps).sum::<f64>() / reports.len() as f64;
    println!(
        "amortized throughput across {} warm queries: {:.1} MTEPS",
        reports.len(),
        amortized
    );

    assert!(
        speedup >= 5.0,
        "expected >= 5x amortization for warm queries, measured {speedup:.1}x \
         (cold {cold_seconds:.4}s vs warm avg {warm_avg:.4}s)"
    );
    println!("OK: warm queries are >= 5x cheaper than the cold query");
    Ok(())
}
