//! Quickstart: the full JGraph flow in ~25 lines — author (pick a library
//! algorithm), **compile once** (light-weight translation + modeled
//! synthesis/flash), **load once** (graph preprocessing + transport), then
//! **run many** cheap queries.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use jgraph::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A graph. Synthetic stand-in for SNAP email-Eu-core
    //    (1,005 vertices / 25,571 edges, power-law).
    let graph = jgraph::graph::generate::email_eu_core_like(1);

    // 2. An algorithm from the library (25+ DSL interfaces; see
    //    `jgraph report --interfaces`).
    let program = algorithms::bfs();

    // 3. Compile once: DSL -> hardware module graph -> compact HDL + host C
    //    + parallelism schedule + XLA artifact lookup. The session owns
    //    process-wide state (device model, PJRT registry).
    let session = Session::new(SessionConfig::default());
    let pipeline = session.compile(&program)?;
    let design = pipeline.design();
    println!(
        "compiled {} via the light-weight flow: {} HDL lines, {} modules, \
         {:.3} ms translate time",
        design.program_name,
        design.hdl_lines,
        design.module_graph.instances.len(),
        design.translate_seconds * 1e3
    );

    // 4. Load once: Layout (CSR) + transport onto the simulated Alveo
    //    U200. Flash and preprocessing are paid here, not per query.
    let mut bound = pipeline.load(&graph, PrepOptions::named("email-Eu-core(synthetic)"))?;

    // 5. Run many: each query only pays the superstep loop. The numeric
    //    result comes from the AOT-compiled XLA superstep when artifacts
    //    are available (cross-checked against the software oracle), and
    //    falls back to the software GAS engine otherwise.
    for root in [0u32, 3, 11] {
        let report = bound.run(&RunOptions::from_root(root))?;
        println!(
            "BFS from {root}: {} supersteps, {:.1} us simulated exec -> {:.1} MTEPS [{}]",
            report.supersteps,
            report.sim_exec_seconds * 1e6,
            report.simulated_mteps,
            match report.functional_path {
                FunctionalPath::Xla => "XLA",
                FunctionalPath::Software => "software oracle",
            }
        );
    }
    println!(
        "one-time setup {:.1}s (modeled prep+compile+deploy), amortized over {} queries",
        bound.setup_seconds(),
        bound.queries_run()
    );
    Ok(())
}
