//! Quickstart: the full JGraph flow in ~20 lines — author (pick a library
//! algorithm), translate (light-weight flow), execute (AOT/XLA functional
//! path + cycle-simulated U200 timing), inspect.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use jgraph::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. A graph. Synthetic stand-in for SNAP email-Eu-core
    //    (1,005 vertices / 25,571 edges, power-law).
    let graph = jgraph::graph::generate::email_eu_core_like(1);

    // 2. An algorithm from the library (25+ DSL interfaces; see
    //    `jgraph report --interfaces`).
    let program = algorithms::bfs();

    // 3. Translate: DSL -> hardware module graph -> compact HDL + host C.
    let design = Translator::jgraph().translate(&program)?;
    println!(
        "translated {} via the light-weight flow: {} HDL lines, {} modules, \
         {:.3} ms translate time",
        design.program_name,
        design.hdl_lines,
        design.module_graph.instances.len(),
        design.translate_seconds * 1e3
    );

    // 4. Execute on the simulated Alveo U200. The numeric result comes
    //    from the AOT-compiled XLA superstep (JAX + Pallas, zero Python at
    //    run time) and is cross-checked against the software oracle.
    let mut executor = Executor::new(ExecutorConfig {
        graph_name: "email-Eu-core(synthetic)".into(),
        ..Default::default()
    });
    let report = executor.run(&program, &design, &graph)?;
    println!("{}", report.summary());
    println!(
        "simulated FPGA execution: {:.1} us over {} supersteps -> {:.1} MTEPS",
        report.sim_exec_seconds * 1e6,
        report.supersteps,
        report.simulated_mteps
    );
    Ok(())
}
