//! A miniature multi-query server: one compiled design + one prepared
//! graph serving a 64-root BFS sweep **concurrently** — the paper's
//! "synthesize once, then serve many fast traversals" economics scaled to
//! query traffic.
//!
//! The binding is immutable while serving: every query carries its own
//! `QueryContext` (scheduler, simulator, trace, DMA records), so
//! `run_batch_parallel` fans the sweep out over OS threads sharing the
//! design and graph read-only, then merges the per-query DMA accounting
//! deterministically. Every modeled report field is identical to the
//! sequential path — asserted below — and wall-clock drops with cores.
//!
//! ```sh
//! cargo run --release --example query_server
//! ```

use std::time::Instant;

use jgraph::prelude::*;

const NUM_QUERIES: usize = 64;
const NUM_WORKERS: usize = 4;

fn main() -> anyhow::Result<()> {
    // ------------------------------------------------------------------
    // one-time: compile the design, prepare + bind the graph
    // ------------------------------------------------------------------
    let graph = jgraph::graph::generate::erdos_renyi(40_000, 160_000, 2026);
    let session = Session::new(SessionConfig::default());
    let pipeline = session.compile(&algorithms::bfs())?;
    let bound = pipeline.load(&graph, PrepOptions::named("er-40k-160k"))?;
    println!(
        "serving {} on {} ({}v/{}e), granted plan {}x{}; one-time setup {:.1}s",
        pipeline.program().name,
        bound.graph().name,
        bound.graph().num_vertices(),
        bound.graph().num_edges(),
        bound.granted_plan().pipelines,
        bound.granted_plan().pes,
        bound.setup_seconds(),
    );

    // a 64-root sweep over vertices that actually have out-edges; the
    // probe is bounded to one lap of the vertex set so an edge-free
    // graph fails loudly instead of spinning forever
    let csr = &bound.graph().csr;
    let n = csr.num_vertices() as u32;
    let queries: Vec<RunOptions> = (0..NUM_QUERIES)
        .map(|i| {
            let start = (i as u32 * 104_729) % n;
            (0..n)
                .map(|probe| (start + probe) % n)
                .find(|&v| csr.degree(v) > 0)
                .map(RunOptions::from_root)
                .ok_or_else(|| anyhow::anyhow!("graph has no vertex with out-edges"))
        })
        .collect::<anyhow::Result<_>>()?;

    // ------------------------------------------------------------------
    // sequential sweep (the baseline run_batch loop)
    // ------------------------------------------------------------------
    let t_seq = Instant::now();
    let sequential: Vec<RunReport> =
        queries.iter().map(|q| bound.query(q)).collect::<anyhow::Result<_>>()?;
    let seq_seconds = t_seq.elapsed().as_secs_f64();

    // ------------------------------------------------------------------
    // concurrent sweep over the same (immutable) binding
    // ------------------------------------------------------------------
    let t_par = Instant::now();
    let parallel = bound.run_batch_parallel(&queries, NUM_WORKERS)?;
    let par_seconds = t_par.elapsed().as_secs_f64();

    // ------------------------------------------------------------------
    // the server contract: concurrency changes wall-clock, not answers
    // ------------------------------------------------------------------
    for (i, (p, s)) in parallel.iter().zip(&sequential).enumerate() {
        assert_eq!(p.supersteps, s.supersteps, "query {i}");
        assert_eq!(p.edges_traversed, s.edges_traversed, "query {i}");
        assert_eq!(
            p.simulated_mteps.to_bits(),
            s.simulated_mteps.to_bits(),
            "query {i}: modeled throughput must not depend on threading"
        );
        assert_eq!(p.transfer_seconds.to_bits(), s.transfer_seconds.to_bits(), "query {i}");
    }
    // the shared ledger merged both sweeps over this one binding:
    // the graph transport plus one 4-byte-per-vertex read-back per query
    let graph_bytes = bound.graph().csr.byte_size() as u64;
    let readback_bytes = 2 * NUM_QUERIES as u64 * 4 * n as u64;
    assert_eq!(
        bound.comm().bytes_moved(),
        graph_bytes + readback_bytes,
        "merged DMA accounting must cover every query exactly once"
    );

    let n_ok = parallel.len();
    println!("{n_ok} queries: every parallel report identical to the sequential sweep");

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let speedup = seq_seconds / par_seconds;
    let qps_seq = NUM_QUERIES as f64 / seq_seconds;
    let qps_par = NUM_QUERIES as f64 / par_seconds;
    println!(
        "sequential: {:.1} ms total ({:.0} queries/s)\n\
         parallel  : {:.1} ms total ({:.0} queries/s) with {} workers on {} cores\n\
         speedup   : {:.2}x",
        seq_seconds * 1e3,
        qps_seq,
        par_seconds * 1e3,
        qps_par,
        NUM_WORKERS,
        cores,
        speedup
    );

    // This example doubles as a CI smoke step on shared (noisy-neighbor)
    // runners, where wall-clock gates flake. The correctness contract
    // (identical reports, merged ledger) is asserted hard above; the only
    // wall-clock assertion here is "parallelism must not badly regress".
    // The strict >= 2x @ 4 workers acceptance gate lives in
    // `benches/batch_parallel.rs`, meant for quiet dedicated hardware.
    assert!(speedup >= 0.8, "parallel sweep regressed badly on {cores} cores: {speedup:.2}x");
    if speedup >= 2.0 {
        println!("OK: parallel sweep wins ({speedup:.2}x) with {NUM_WORKERS} workers");
    } else {
        println!(
            "OK (informational): {speedup:.2}x on {cores} cores; \
             see benches/batch_parallel.rs for the gated measurement"
        );
    }
    Ok(())
}
