//! First-class runtime parameters: one `Session::compile`, one prepared
//! graph, a 16-point damping sweep — zero recompiles.
//!
//! Before this API, `pagerank(0.9, tol)` baked the damping into the
//! program (and its kernel name), forcing a fresh translate/synthesis per
//! value. Now `pagerank()` *declares* `damping`/`tolerance` and every
//! query binds its own values into the design's argument register file:
//! the emitted HDL, the sanitized kernel name, and the AOT artifact key
//! are identical across the whole sweep.
//!
//! ```sh
//! cargo run --release --example param_sweep
//! ```

use jgraph::prelude::*;

const SWEEP_POINTS: usize = 16;

fn main() -> anyhow::Result<()> {
    let graph = jgraph::graph::generate::rmat(12, 120_000, 0.57, 0.19, 0.19, 2026);

    let session = Session::new(SessionConfig::default());

    // ------------------------------------------------------------------
    // compile ONCE: the design is parameter-independent
    // ------------------------------------------------------------------
    let pipeline = session.compile(&algorithms::pagerank())?;
    println!(
        "compiled {:?} once: {} HDL lines, params declared: {:?}",
        pipeline.program().name,
        pipeline.design().hdl_lines,
        pipeline.params().names(),
    );
    let bound = pipeline.load(&graph, PrepOptions::named("rmat-12"))?;

    // ------------------------------------------------------------------
    // 16-point damping sweep, each query binding its own value
    // ------------------------------------------------------------------
    // damping in [0.05, 0.85]: the engine's 200-superstep safety bound
    // caps how stiff a (damping, tolerance) pair may be — delta decays
    // ~damping^k, so 0.85 @ 1e-8 needs ~115 sweeps, comfortably inside it
    let queries: Vec<RunOptions> = (0..SWEEP_POINTS)
        .map(|i| {
            let damping = 0.05 + 0.8 * i as f64 / (SWEEP_POINTS - 1) as f64;
            RunOptions::default().bind("damping", damping).bind("tolerance", 1e-8)
        })
        .collect();

    let parallel = bound.run_batch_parallel(&queries, 4)?;

    println!("\n{:>8} | {:>10} | {:>12} | {:>10}", "damping", "supersteps", "edges", "MTEPS");
    for r in &parallel {
        let damping = r.bound_params.iter().find(|(n, _)| n == "damping").unwrap().1;
        println!(
            "{damping:>8.3} | {:>10} | {:>12} | {:>10.1}",
            r.supersteps, r.edges_traversed, r.simulated_mteps
        );
    }

    // ------------------------------------------------------------------
    // the redesign's guarantees, asserted
    // ------------------------------------------------------------------
    // (1) one compile served the whole sweep
    assert_eq!(bound.queries_run(), SWEEP_POINTS as u64);

    // (2) parallel parameter sweeps report identically to sequential ones
    let mut seq_bound = pipeline.load(&graph, PrepOptions::named("rmat-12"))?;
    let sequential = seq_bound.run_batch(&queries)?;
    for (p, q) in parallel.iter().zip(&sequential) {
        assert_eq!(p.bound_params, q.bound_params);
        assert_eq!(p.supersteps, q.supersteps);
        assert_eq!(p.edges_traversed, q.edges_traversed);
        assert_eq!(p.query_seconds.to_bits(), q.query_seconds.to_bits());
    }

    // (3) damping genuinely changes the computation (more damping = a
    // stiffer fixpoint = more supersteps to the same tolerance)
    assert!(
        parallel.first().unwrap().supersteps < parallel.last().unwrap().supersteps,
        "damping sweep must change convergence behaviour"
    );

    println!(
        "\nOK: {} damping points served by one compile ({} queries, 0 recompiles)",
        SWEEP_POINTS,
        bound.queries_run()
    );
    Ok(())
}
