//! Road-network shortest paths — the paper's Table I telecom/supply-chain
//! workload family (SSSP). Uses a 2-D grid graph (the opposite locality
//! regime from power-law) and demonstrates the *preprocessing* interfaces
//! under the compile-once lifecycle: one `Session::compile`, then one
//! `load` per preprocessing configuration — Layout, Reorder, and
//! Partition — with their measured effect on the simulated design.
//!
//! ```sh
//! cargo run --release --example roadnet_sssp
//! ```

use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::partition::{partition, PartitionStrategy};
use jgraph::prep::prepared::PrepOptions;
use jgraph::prep::reorder::ReorderStrategy;

fn main() -> anyhow::Result<()> {
    // 96x96 grid road network, randomly shuffled vertex ids (as road data
    // usually arrives), weighted edges = travel times
    let grid = generate::grid2d(96, 96, 7);
    let mut rng = jgraph::graph::SplitMix64::new(99);
    let mut shuffle: Vec<u32> = (0..grid.num_vertices as u32).collect();
    for i in (1..shuffle.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        shuffle.swap(i, j);
    }
    let road = grid.permute(&shuffle);

    // compile SSSP once; every preprocessing variant below reuses it
    let session = Session::new(SessionConfig::default());
    let pipeline = session.compile(&algorithms::sssp())?;
    println!(
        "road network: {} intersections, {} road segments",
        road.num_vertices,
        road.num_edges()
    );

    // --- Reorder ablation: locality matters for the row-start model
    for strategy in [None, Some(ReorderStrategy::BfsLocality)] {
        let mut prep = PrepOptions::named("roadnet-96x96");
        prep.reorder = strategy;
        let mut bound = pipeline.load(&road, prep)?;
        let report = bound.run(&RunOptions::default())?;
        println!(
            "  reorder {:?}: {:>7.2} MTEPS, row-start cycles {}",
            strategy.map(|_| "bfs-locality").unwrap_or("none"),
            report.simulated_mteps,
            report.sim.cycles.row_start
        );
    }

    // --- Partition interfaces (for multi-PE placement)
    for strategy in [PartitionStrategy::Hash, PartitionStrategy::BfsGrow] {
        let p = partition(&road, 4, strategy)?;
        println!(
            "  partition {:?} x4: cut {:.1}% of edges, imbalance {:.2}",
            strategy,
            100.0 * p.cut_fraction(road.num_edges()),
            p.edge_imbalance()
        );
    }

    // --- the actual shortest paths (functional path)
    let csr = jgraph::graph::csr::Csr::from_edgelist(&road);
    let result = jgraph::engine::gas::run(&algorithms::sssp(), &csr, 0, |_| {})?;
    let reachable = result.values.iter().filter(|v| v.is_finite()).count();
    let max_dist = result.values.iter().filter(|v| v.is_finite()).fold(0.0f64, |a, &b| a.max(b));
    println!(
        "SSSP from intersection 0: {} reachable, max travel time {:.1}, {} relaxation sweeps",
        reachable, max_dist, result.supersteps
    );
    Ok(())
}
