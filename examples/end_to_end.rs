//! End-to-end validation driver (DESIGN.md §5 "E2E"): proves all layers
//! compose on the paper's full workload.
//!
//! Pipeline exercised, per graph × algorithm:
//!   graph generator (SNAP stand-ins) → DSL program → `Session::compile`
//!   (light-weight translator: HDL + host C + resources, compiled once per
//!   flow) → `CompiledPipeline::load` (communication manager: simulated
//!   XRT/PCIe, once per graph) → runtime scheduler → **AOT XLA supersteps**
//!   (JAX+Pallas lowered at build time, executed via PJRT from rust,
//!   cross-checked against the software GAS oracle; software fallback when
//!   artifacts are absent) → cycle-simulated U200 timing → the paper's
//!   headline metric (MTEPS).
//!
//! This regenerates Table V (both graphs, all three translators) and the
//! headline claim ("up to 300 MTEPS BFS within tens of seconds"); the
//! run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use jgraph::dsl::algorithms;
use jgraph::engine::{FunctionalPath, RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::translator::{Translator, TranslatorKind};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    println!("=== JGraph end-to-end validation ===\n");

    // --- the paper's two evaluation graphs (synthetic stand-ins)
    let graphs = vec![
        ("email-Eu-core (synthetic)", generate::email_eu_core_like(42)),
        ("soc-Slashdot0922 (synthetic)", generate::soc_slashdot_like(42)),
    ];
    for (name, g) in &graphs {
        let stats = jgraph::graph::properties::GraphStats::compute(g);
        println!(
            "graph {name}: {} vertices, {} edges, max out-degree {}, \
             power-law alpha {:.2}",
            stats.num_vertices,
            stats.num_edges,
            stats.max_out_degree,
            stats.power_law_alpha.unwrap_or(f64::NAN)
        );
    }
    println!();

    // --- Table V: BFS through all three flows on both graphs; the XLA
    //     functional path drives the values when artifacts are built
    let session = Session::new(SessionConfig::default());
    let program = algorithms::bfs();
    println!("--- Table V reproduction (BFS) ---");
    println!(
        "{:<12} {:>10} {:<28} {:>8} {:>12}  {}",
        "Work", "Code lines", "Graph", "RT(s)", "TP(MTEPS)", "functional path"
    );
    let mut max_mteps: f64 = 0.0;
    let mut xla_live = false;
    for kind in TranslatorKind::all() {
        // compile once per flow, bind once per graph
        let compiled = session.compile_with(Translator::of_kind(kind), &program)?;
        for (name, el) in &graphs {
            let mut bound = compiled.load(el, PrepOptions::named(*name))?;
            let r = bound.run(&RunOptions::default())?;
            let path = match r.functional_path {
                FunctionalPath::Xla => {
                    xla_live = true;
                    assert!(r.oracle_deviation.unwrap_or(1.0) < 1e-3, "oracle cross-check");
                    format!("XLA (dev {:.1e})", r.oracle_deviation.unwrap())
                }
                FunctionalPath::Software => "software oracle".to_string(),
            };
            println!(
                "{:<12} {:>10} {:<28} {:>8.1} {:>12.2}  {path}",
                r.translator, r.hdl_lines, name, r.rt_seconds, r.simulated_mteps,
            );
            if kind == TranslatorKind::JGraph {
                max_mteps = max_mteps.max(r.simulated_mteps);
            }
        }
    }
    if !xla_live {
        println!(
            "note: AOT artifacts not available in this checkout — values came \
             from the software GAS oracle (run `make artifacts` + build with \
             --features pjrt for the XLA path)"
        );
    }
    println!(
        "\nheadline: FAgraph BFS peaks at {:.0} MTEPS (paper: \"up to 300 MTEPS \
         ... within tens of seconds\")\n",
        max_mteps
    );
    assert!(max_mteps >= 300.0, "headline claim not reproduced");

    // --- every canonical algorithm through the full stack on the small
    //     graph: compile once per algorithm, many graphs/queries possible
    println!("--- all canonical algorithms, full stack, email-Eu-core ---");
    for program in algorithms::all_canonical() {
        let compiled = session.compile(&program)?;
        let mut bound = compiled.load(&graphs[0].1, PrepOptions::named("email-Eu-core"))?;
        let r = bound.run(&RunOptions::default())?;
        println!(
            "  {:<18} {:>3} supersteps  {:>8.1} MTEPS  exec(functional) {:>7.1} ms  \
             oracle dev {:.1e}",
            r.program,
            r.supersteps,
            r.simulated_mteps,
            r.functional_exec_seconds * 1e3,
            r.oracle_deviation.unwrap_or(0.0)
        );
    }

    println!("\nend-to-end validation completed in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
