//! End-to-end validation driver (DESIGN.md §5 "E2E"): proves all layers
//! compose on the paper's full workload.
//!
//! Pipeline exercised, per graph × algorithm:
//!   graph generator (SNAP stand-ins) → DSL program → light-weight
//!   translator (HDL + host C + resources) → communication manager
//!   (simulated XRT/PCIe) → runtime scheduler → **AOT XLA supersteps**
//!   (JAX+Pallas lowered at build time, executed via PJRT from rust,
//!   cross-checked against the software GAS oracle) → cycle-simulated
//!   U200 timing → the paper's headline metric (MTEPS).
//!
//! This regenerates Table V (both graphs, all three translators) and the
//! headline claim ("up to 300 MTEPS BFS within tens of seconds"); the
//! run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example end_to_end
//! ```

use std::time::Instant;

use jgraph::dsl::algorithms;
use jgraph::engine::{Executor, ExecutorConfig, FunctionalPath};
use jgraph::graph::generate;
use jgraph::translator::{Translator, TranslatorKind};

fn main() -> anyhow::Result<()> {
    let t0 = Instant::now();
    println!("=== JGraph end-to-end validation ===\n");

    // --- the paper's two evaluation graphs (synthetic stand-ins)
    let graphs = vec![
        ("email-Eu-core (synthetic)", generate::email_eu_core_like(42)),
        ("soc-Slashdot0922 (synthetic)", generate::soc_slashdot_like(42)),
    ];
    for (name, g) in &graphs {
        let stats = jgraph::graph::properties::GraphStats::compute(g);
        println!(
            "graph {name}: {} vertices, {} edges, max out-degree {}, \
             power-law alpha {:.2}",
            stats.num_vertices,
            stats.num_edges,
            stats.max_out_degree,
            stats.power_law_alpha.unwrap_or(f64::NAN)
        );
    }
    println!();

    // --- Table V: BFS through all three flows on both graphs, with the
    //     XLA functional path live (not simulation-only)
    println!("--- Table V reproduction (BFS, XLA functional path ON) ---");
    println!(
        "{:<12} {:>10} {:<28} {:>8} {:>12}  {}",
        "Work", "Code lines", "Graph", "RT(s)", "TP(MTEPS)", "functional path"
    );
    let program = algorithms::bfs();
    let mut max_mteps: f64 = 0.0;
    for kind in TranslatorKind::all() {
        let design = Translator::of_kind(kind).translate(&program)?;
        for (name, el) in &graphs {
            let mut ex = Executor::new(ExecutorConfig {
                graph_name: name.to_string(),
                ..Default::default()
            });
            let r = ex.run(&program, &design, el)?;
            assert_eq!(r.functional_path, FunctionalPath::Xla, "AOT path must be live");
            assert!(r.oracle_deviation.unwrap_or(1.0) < 1e-3, "oracle cross-check");
            println!(
                "{:<12} {:>10} {:<28} {:>8.1} {:>12.2}  XLA (dev {:.1e})",
                r.translator,
                r.hdl_lines,
                name,
                r.rt_seconds,
                r.simulated_mteps,
                r.oracle_deviation.unwrap()
            );
            if kind == TranslatorKind::JGraph {
                max_mteps = max_mteps.max(r.simulated_mteps);
            }
        }
    }
    println!(
        "\nheadline: FAgraph BFS peaks at {:.0} MTEPS (paper: \"up to 300 MTEPS \
         ... within tens of seconds\")\n",
        max_mteps
    );
    assert!(max_mteps >= 300.0, "headline claim not reproduced");

    // --- every canonical algorithm through the full stack on the small
    //     graph: translation, XLA execution, oracle verification
    println!("--- all canonical algorithms, full stack, email-Eu-core ---");
    for program in algorithms::all_canonical() {
        let design = Translator::jgraph().translate(&program)?;
        let mut ex = Executor::new(ExecutorConfig {
            graph_name: "email-Eu-core".into(),
            ..Default::default()
        });
        let r = ex.run(&program, &design, &graphs[0].1)?;
        println!(
            "  {:<18} {:>3} supersteps  {:>8.1} MTEPS  exec(XLA) {:>7.1} ms  \
             oracle dev {:.1e}",
            r.program,
            r.supersteps,
            r.simulated_mteps,
            r.functional_exec_seconds * 1e3,
            r.oracle_deviation.unwrap_or(0.0)
        );
    }

    println!("\nend-to-end validation completed in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
