//! End-to-end smoke of the `jgraph serve` daemon: start a server on an
//! ephemeral port, push 32 mixed queries (2 graphs x 2 algorithms x
//! 3 tenants) through a real TCP client, read the rolling stats, then
//! drain and join cleanly. This is the CI serve smoke — every assertion
//! here is a protocol contract, not a timing gate.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use std::sync::Arc;
use std::time::Duration;

use jgraph::engine::{Session, SessionConfig};
use jgraph::sched::FaultPlan;
use jgraph::serve::wire::DEFAULT_TENANT;
use jgraph::serve::{QueryRequest, ServeClient, ServeConfig, ServeRegistry, Server};

fn query(graph: &str, algo: &str, root: u32, tenant: &str) -> QueryRequest {
    QueryRequest {
        graph: graph.into(),
        algo: algo.into(),
        root,
        params: Vec::new(),
        direction: None,
        tenant: tenant.into(),
        max_supersteps: None,
        deadline_us: None,
    }
}

fn main() -> anyhow::Result<()> {
    // in-process daemon: software oracle only, so the smoke runs the
    // same everywhere (no XLA artifacts required)
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let registry = Arc::new(ServeRegistry::new(session, 4));
    registry.register_edges("er", jgraph::graph::generate::erdos_renyi(2_000, 12_000, 7));
    registry.register_edges("grid", jgraph::graph::generate::grid2d(32, 32, 7));
    // chaos smoke: a JGRAPH_FAULT_PLAN in the environment arms the
    // deterministic fault harness — every assertion below must still
    // hold (transient faults are retried to success, the daemon never
    // dies), which is exactly what CI drills
    let fault_plan = FaultPlan::from_env()?;
    let config = ServeConfig {
        batch_window: Duration::from_millis(3),
        fault_plan: fault_plan.clone(),
        ..Default::default()
    };
    let server = Server::start(config, registry)?;
    let addr = server.local_addr();
    println!("serve_demo: daemon on {addr}");
    if let Some(plan) = &fault_plan {
        println!("serve_demo: chaos plan armed: {} (seed {})", plan.source(), plan.seed());
    }

    // -------- phase 1: 32 mixed queries, pipelined per tenant ---------
    let tenants = [DEFAULT_TENANT, "alice", "bob"];
    let mut clients: Vec<ServeClient> =
        tenants.iter().map(|_| ServeClient::connect(addr)).collect::<anyhow::Result<_>>()?;
    let mut sent = vec![0usize; tenants.len()];
    for i in 0..32u32 {
        let t = (i as usize) % tenants.len();
        let graph = if i % 2 == 0 { "er" } else { "grid" };
        let algo = if i % 4 < 2 { "bfs" } else { "pagerank" };
        clients[t].send_query(&query(graph, algo, i % 100, tenants[t]))?;
        sent[t] += 1;
    }
    let mut ok = 0usize;
    for (t, client) in clients.iter_mut().enumerate() {
        for _ in 0..sent[t] {
            let resp = client.recv()?;
            assert_eq!(
                resp.get("ok").and_then(|v| v.as_bool()),
                Some(true),
                "query failed: {}",
                resp.render()
            );
            let report = resp.get("report").expect("response carries the full report");
            assert!(report.get("supersteps").unwrap().as_u64().unwrap() > 0);
            ok += 1;
        }
    }
    println!("serve_demo: {ok}/32 queries served");
    assert_eq!(ok, 32);

    // -------- phase 2: stats reflect the traffic ----------------------
    let stats = clients[0].stats()?;
    assert_eq!(stats.get("served").unwrap().as_u64(), Some(32));
    assert_eq!(stats.get("errors").unwrap().as_u64(), Some(0));
    assert!(stats.get("batches").unwrap().as_u64().unwrap() >= 1);
    assert!(stats.get("resident_graphs").unwrap().as_u64().unwrap() <= 4);
    let p99 = stats.get("total").unwrap().get("p99_us").unwrap().as_u64().unwrap();
    println!(
        "serve_demo: p50/p99 total latency {} / {} us, mean batch occupancy {:.2}",
        stats.get("total").unwrap().get("p50_us").unwrap().as_u64().unwrap(),
        p99,
        stats.get("mean_batch_occupancy").unwrap().as_f64().unwrap(),
    );
    if fault_plan.is_some() {
        // the chaos plan must be transient and attempt-0-keyed (retries
        // absorb every fault): all 32 queries still answered ok above,
        // and the counters prove the harness actually fired
        let injected = stats.get("faults_injected").unwrap().as_u64().unwrap();
        let retried = stats.get("retries_attempted").unwrap().as_u64().unwrap();
        assert!(injected >= 1, "an armed plan must inject at least one fault");
        assert_eq!(
            stats.get("retries_exhausted").unwrap().as_u64(),
            Some(0),
            "a transient-only plan never exhausts the retry budget"
        );
        println!(
            "serve_demo: chaos drill survived — {injected} fault(s) injected, \
             {retried} retr{} absorbed",
            if retried == 1 { "y" } else { "ies" }
        );
    }

    // -------- phase 3: a tenant at cap gets a typed reject ------------
    // cap "metered" at 1 on a second daemon with a long window: the
    // first query parks in the batcher, so the next two must bounce
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });
    let registry = Arc::new(ServeRegistry::new(session, 4));
    registry.register_edges("er", jgraph::graph::generate::erdos_renyi(2_000, 12_000, 7));
    let config = ServeConfig {
        batch_window: Duration::from_millis(400),
        tenant_caps: vec![("metered".into(), 1)],
        ..Default::default()
    };
    let capped = Server::start(config, registry)?;
    let mut c = ServeClient::connect(capped.local_addr())?;
    for _ in 0..3 {
        c.send_query(&query("er", "bfs", 0, "metered"))?;
    }
    let mut served = 0usize;
    let mut rejected = 0usize;
    for _ in 0..3 {
        let resp = c.recv()?;
        if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
            served += 1;
        } else {
            let kind = resp.get("error").unwrap().get("kind").unwrap().as_str().unwrap();
            assert_eq!(kind, "tenant_over_cap", "{}", resp.render());
            rejected += 1;
        }
    }
    assert_eq!(served, 1, "exactly the in-cap query runs");
    assert_eq!(rejected, 2, "over-cap queries reject instead of hanging");
    // capacity returns once the in-flight query finishes
    let resp = c.query(&query("er", "bfs", 1, "metered"))?;
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true));
    println!("serve_demo: tenant cap enforced (1 served, 2 typed rejects, then recovery)");
    drop(c);
    capped.join()?;

    // -------- phase 4: graceful drain ---------------------------------
    let ack = clients[0].shutdown()?;
    assert_eq!(ack.get("ok").unwrap().as_bool(), Some(true));
    drop(clients);
    server.join()?;
    println!("serve_demo: drained and joined cleanly");
    Ok(())
}
