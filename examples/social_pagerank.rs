//! Social-network PageRank — the paper's Table I motivating workload
//! ("Social network: individual/friendship: PR/BFS/DFS").
//!
//! Runs PageRank over a power-law graph through all three translation
//! flows and prints an influencer ranking plus the Table-V-style
//! comparison, showing how the flow (not the algorithm) determines the
//! achieved throughput.
//!
//! ```sh
//! cargo run --release --example social_pagerank
//! ```

use jgraph::dsl::algorithms;
use jgraph::engine::{Executor, ExecutorConfig};
use jgraph::graph::generate;
use jgraph::translator::{Translator, TranslatorKind};

fn main() -> anyhow::Result<()> {
    // a synthetic social graph: 8,192 users, power-law follower counts
    let graph = generate::rmat(13, 180_000, 0.57, 0.19, 0.19, 2024);
    let program = algorithms::pagerank(0.85, 1e-8);

    let mut ranked: Option<Vec<f64>> = None;
    println!("PageRank across translation flows ({} users, {} follows):", graph.num_vertices, graph.num_edges());
    for kind in TranslatorKind::all() {
        let design = Translator::of_kind(kind).translate(&program)?;
        let mut ex = Executor::new(ExecutorConfig {
            graph_name: "social-rmat13".into(),
            ..Default::default()
        });
        let report = ex.run(&program, &design, &graph)?;
        println!(
            "  {:10} | {:>3} HDL lines | {:>8.2} MTEPS | RT {:>5.1}s | {} iterations",
            report.translator,
            report.hdl_lines,
            report.simulated_mteps,
            report.rt_seconds,
            report.supersteps
        );
        ranked = Some(run_values(&program, &design, &graph)?);
    }

    // top influencers from the last run's functional values
    let values = ranked.expect("at least one run");
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].total_cmp(&values[a]));
    println!("top-5 influencers (vertex: rank):");
    for &v in idx.iter().take(5) {
        println!("  v{:>5}: {:.6}", v, values[v]);
    }
    let total: f64 = values.iter().sum();
    println!("rank mass: {total:.6} (should be ~1.0)");
    Ok(())
}

/// Re-run the functional path only to extract vertex values.
fn run_values(
    program: &jgraph::dsl::program::GasProgram,
    _design: &jgraph::translator::Design,
    graph: &jgraph::graph::edgelist::EdgeList,
) -> anyhow::Result<Vec<f64>> {
    let csr = jgraph::graph::csr::Csr::from_edgelist(graph);
    let result = jgraph::engine::gas::run(program, &csr, 0, |_| {})?;
    Ok(result.values)
}
