//! Social-network PageRank — the paper's Table I motivating workload
//! ("Social network: individual/friendship: PR/BFS/DFS").
//!
//! Runs PageRank over a power-law graph through all three translation
//! flows (one `compile` per flow, the graph loaded against each) and
//! prints an influencer ranking plus the Table-V-style comparison, showing
//! how the flow (not the algorithm) determines the achieved throughput.
//!
//! ```sh
//! cargo run --release --example social_pagerank
//! ```

use jgraph::dsl::algorithms;
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;
use jgraph::translator::{Translator, TranslatorKind};

fn main() -> anyhow::Result<()> {
    // a synthetic social graph: 8,192 users, power-law follower counts
    let graph = generate::rmat(13, 180_000, 0.57, 0.19, 0.19, 2024);
    // tolerance binds per query now — the program itself stays generic
    let program = algorithms::pagerank();
    let query = RunOptions::default().bind("tolerance", 1e-8);
    let session = Session::new(SessionConfig::default());

    println!(
        "PageRank across translation flows ({} users, {} follows):",
        graph.num_vertices,
        graph.num_edges()
    );
    for kind in TranslatorKind::all() {
        let compiled = session.compile_with(Translator::of_kind(kind), &program)?;
        let mut bound = compiled.load(&graph, PrepOptions::named("social-rmat13"))?;
        let report = bound.run(&query)?;
        println!(
            "  {:10} | {:>3} HDL lines | {:>8.2} MTEPS | RT {:>5.1}s | {} iterations",
            report.translator,
            report.hdl_lines,
            report.simulated_mteps,
            report.rt_seconds,
            report.supersteps
        );
    }

    // top influencers from the functional values (software oracle), at
    // the same per-query tolerance binding
    let csr = jgraph::graph::csr::Csr::from_edgelist(&graph);
    let oracle = program.instantiate(&jgraph::dsl::ParamSet::new().bind("tolerance", 1e-8))?;
    let values = jgraph::engine::gas::run(&oracle, &csr, 0, |_| {})?.values;
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| values[b].total_cmp(&values[a]));
    println!("top-5 influencers (vertex: rank):");
    for &v in idx.iter().take(5) {
        println!("  v{:>5}: {:.6}", v, values[v]);
    }
    let total: f64 = values.iter().sum();
    println!("rank mass: {total:.6} (should be ~1.0)");
    Ok(())
}
