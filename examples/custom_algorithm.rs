//! Extensibility demo — the paper's central usability claim: "One can
//! program almost all the graph algorithms through changing the Apply
//! interface."
//!
//! Builds two *custom* algorithms the library does not ship, straight from
//! the function-level DSL, and compiles them with the builder's terminal
//! `compile(&session)` — no new RTL, no new kernels, no framework changes.
//! Validation failures surface as typed `CompileError`s, not panics.
//!
//! ```sh
//! cargo run --release --example custom_algorithm
//! ```

use jgraph::dsl::apply::{ApplyExpr, BinOp, UnOp};
use jgraph::dsl::builder::GasProgramBuilder;
use jgraph::dsl::program::{Convergence, FrontierPolicy, InitPolicy, ReduceOp, StateType, Writeback};
use jgraph::engine::{RunOptions, Session, SessionConfig};
use jgraph::graph::generate;
use jgraph::prep::prepared::PrepOptions;

fn main() -> anyhow::Result<()> {
    let graph = generate::rmat(11, 40_000, 0.57, 0.19, 0.19, 5);
    // custom programs have no AOT kernel; they run on the software engine
    let session = Session::new(SessionConfig { use_xla: false, ..Default::default() });

    // --- Custom #1: "hop-penalized distance" — SSSP where every hop also
    //     costs sqrt(weight): Apply = src + w + sqrt(w), Reduce = min.
    let hop_penalized = GasProgramBuilder::new("hop-penalized-sssp")
        .state(StateType::F32)
        .init(InitPolicy::root_and_default(0.0, f64::INFINITY))
        .apply(ApplyExpr::bin(
            BinOp::Add,
            ApplyExpr::src().add(ApplyExpr::weight()),
            ApplyExpr::un(UnOp::Sqrt, ApplyExpr::weight()),
        ))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .compile(&session)?;

    // --- Custom #2: "reach score" — every vertex accumulates the squared
    //     weights of incoming edges (one sweep): Apply = w*w, Reduce = sum.
    let reach_score = GasProgramBuilder::new("reach-score")
        .state(StateType::F32)
        .apply(ApplyExpr::un(UnOp::Square, ApplyExpr::weight()))
        .reduce(ReduceOp::Sum)
        .convergence(Convergence::FixedIterations(1))
        .compile(&session)?;

    for pipeline in [&hop_penalized, &reach_score] {
        // the same translator that handled the library algorithms handles
        // these: the Apply expression becomes an ALU chain
        let program = pipeline.program();
        println!(
            "custom algorithm {:?}: apply = {}, {} ALU op(s)/lane, {} HDL lines",
            program.name,
            program.apply.render(),
            program.apply.op_count(),
            pipeline.design().hdl_lines
        );
        let mut bound = pipeline.load(&graph, PrepOptions::named("rmat-11"))?;
        let report = bound.run(&RunOptions::default())?;
        println!(
            "  -> {} supersteps, {:.1} MTEPS simulated, {} edges traversed",
            report.supersteps, report.simulated_mteps, report.edges_traversed
        );
    }

    // sanity: hop-penalized distances dominate plain SSSP distances
    let csr = jgraph::graph::csr::Csr::from_edgelist(&graph);
    let plain = jgraph::engine::gas::run(&jgraph::dsl::algorithms::sssp(), &csr, 0, |_| {})?;
    let penal = jgraph::engine::gas::run(hop_penalized.program(), &csr, 0, |_| {})?;
    let dominated = plain
        .values
        .iter()
        .zip(&penal.values)
        .filter(|(p, _)| p.is_finite())
        .all(|(p, q)| q + 1e-9 >= *p);
    println!("hop-penalized >= plain SSSP on every reachable vertex: {dominated}");
    Ok(())
}
