//! Extensibility demo — the paper's central usability claim: "One can
//! program almost all the graph algorithms through changing the Apply
//! interface."
//!
//! Builds two *custom* algorithms the library does not ship, straight from
//! the function-level DSL (builder + Apply expression language), translates
//! them with the light-weight flow, and runs them — no new RTL, no new
//! kernels, no framework changes.
//!
//! ```sh
//! cargo run --release --example custom_algorithm
//! ```

use jgraph::dsl::apply::{ApplyExpr, BinOp, UnOp};
use jgraph::dsl::builder::GasProgramBuilder;
use jgraph::dsl::program::{Convergence, FrontierPolicy, InitPolicy, ReduceOp, StateType, Writeback};
use jgraph::engine::{Executor, ExecutorConfig};
use jgraph::graph::generate;
use jgraph::translator::Translator;

fn main() -> anyhow::Result<()> {
    let graph = generate::rmat(11, 40_000, 0.57, 0.19, 0.19, 5);

    // --- Custom #1: "hop-penalized distance" — SSSP where every hop also
    //     costs sqrt(weight): Apply = src + w + sqrt(w), Reduce = min.
    let hop_penalized = GasProgramBuilder::new("hop-penalized-sssp")
        .state(StateType::F32)
        .init(InitPolicy::RootAndDefault { root_value: 0.0, default: f64::INFINITY })
        .apply(ApplyExpr::bin(
            BinOp::Add,
            ApplyExpr::src().add(ApplyExpr::weight()),
            ApplyExpr::un(UnOp::Sqrt, ApplyExpr::weight()),
        ))
        .reduce(ReduceOp::Min)
        .writeback(Writeback::MinCombine)
        .frontier(FrontierPolicy::All)
        .convergence(Convergence::NoChange)
        .build()?;

    // --- Custom #2: "reach score" — every vertex accumulates the squared
    //     weights of incoming edges (one sweep): Apply = w*w, Reduce = sum.
    let reach_score = GasProgramBuilder::new("reach-score")
        .state(StateType::F32)
        .apply(ApplyExpr::un(UnOp::Square, ApplyExpr::weight()))
        .reduce(ReduceOp::Sum)
        .convergence(Convergence::FixedIterations(1))
        .build()?;

    for program in [&hop_penalized, &reach_score] {
        // the same translator that handled the library algorithms handles
        // these: the Apply expression becomes an ALU chain
        let design = Translator::jgraph().translate(program)?;
        println!(
            "custom algorithm {:?}: apply = {}, {} ALU op(s)/lane, {} HDL lines",
            program.name,
            program.apply.render(),
            program.apply.op_count(),
            design.hdl_lines
        );
        let mut ex = Executor::new(ExecutorConfig {
            use_xla: false, // custom programs run on the software GAS engine
            graph_name: "rmat-11".into(),
            ..Default::default()
        });
        let report = ex.run(program, &design, &graph)?;
        println!(
            "  -> {} supersteps, {:.1} MTEPS simulated, {} edges traversed",
            report.supersteps, report.simulated_mteps, report.edges_traversed
        );
    }

    // sanity: hop-penalized distances dominate plain SSSP distances
    let csr = jgraph::graph::csr::Csr::from_edgelist(&graph);
    let plain = jgraph::engine::gas::run(&jgraph::dsl::algorithms::sssp(), &csr, 0, |_| {})?;
    let penal = jgraph::engine::gas::run(&hop_penalized, &csr, 0, |_| {})?;
    let dominated = plain
        .values
        .iter()
        .zip(&penal.values)
        .filter(|(p, _)| p.is_finite())
        .all(|(p, q)| q + 1e-9 >= *p);
    println!("hop-penalized >= plain SSSP on every reachable vertex: {dominated}");
    Ok(())
}
